"""Single-file parallel checkpointing of sharded pytrees.

This is the paper's technique applied to the checkpoint path of a training
framework: every (virtual) host serializes + compresses its parameter
shards into relocatable clusters of ONE RNT-J file in parallel — no
per-host file tree and no post-hoc merge step (contrast: Orbax/tensorstore
write per-host files = the paper's "independent files + merge" baseline).

Checkpoint schema (nested, variable length — exactly the data shape the
format exists for)::

    entry := { param_id:int32, shard_index:int32,
               shape:[int64], row_start:int64, row_end:int64,
               data:[uint8] }

Entry param_id == -1 carries the JSON manifest (tree structure, names,
dtypes, step metadata).  Restore is mesh-shape-agnostic: clusters are
self-describing, so any number of readers can re-partition them (elastic
restart across different host counts).
"""

from __future__ import annotations

import dataclasses
import json
import multiprocessing
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import ParallelWriter, RNTJReader, WriteOptions
from repro.core.mpwrite import MultiWriterCoordinator

from ._mpworker import CKPT_SCHEMA, _entry_batch, _np_dtype, run_save_worker


def _flatten_with_names(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out.append((name, leaf))
    return out, treedef


def _host_arrays(leaves) -> List[np.ndarray]:
    def _host(l):
        a = np.asarray(l)
        # ascontiguousarray promotes 0-d to 1-d; keep true rank
        return np.ascontiguousarray(a) if a.ndim else a

    return [_host(l) for _, l in leaves]


def _work_units(arrays: List[np.ndarray],
                row_block_bytes: int) -> List[Tuple[int, int, int]]:
    """(param_id, row range) blocks so large tensors spread across
    writers; every unit is independent (paper §1's reorderable rows)."""
    units: List[Tuple[int, int, int]] = []
    for pid, arr in enumerate(arrays):
        rows = arr.shape[0] if arr.ndim else 1
        row_bytes = max(1, arr.nbytes // max(rows, 1))
        block = max(1, row_block_bytes // row_bytes)
        start = 0
        while start < rows or (rows == 0 and start == 0):
            end = min(rows, start + block)
            units.append((pid, start, end))
            if end >= rows:
                break
            start = end
    return units


def _build_manifest(leaves, metadata: Optional[Dict]) -> Dict:
    return {
        "names": [n for n, _ in leaves],
        "dtypes": [str(l.dtype) for _, l in leaves],
        "shapes": [list(np.shape(l)) for _, l in leaves],
        "treedef": None,  # reconstructed from names at load
        "metadata": metadata or {},
    }


def _manifest_entry(manifest: Dict) -> Dict:
    return {
        "param_id": -1, "shard_index": 0, "shape": [],
        "row_start": 0, "row_end": 0,
        "data": json.dumps(manifest).encode(),
    }


def _unit_entry(arrays, u: int, unit: Tuple[int, int, int]) -> Dict:
    pid, r0, r1 = unit
    arr = arrays[pid]
    piece = arr[r0:r1] if arr.ndim else arr
    return {
        "param_id": pid, "shard_index": u,
        "shape": list(arr.shape),
        "row_start": r0, "row_end": r1,
        "data": piece.tobytes(),
    }


def save_checkpoint(
    path: str,
    tree,
    n_writers: int = 4,
    row_block_bytes: int = 4 * 1024 * 1024,
    options: Optional[WriteOptions] = None,
    metadata: Optional[Dict] = None,
) -> Dict:
    """Parallel single-file save.

    ``n_writers`` simulates hosts: work (leaf row-blocks) is partitioned
    round-robin; each writer thread owns a fill context and commits its
    clusters through the shared reserve+metadata critical section.  In a
    real multi-host deployment each jax process runs one writer over its
    addressable shards and the critical section is the coordinator's
    extent ledger (DESIGN.md §3.2).
    """
    # journal=False: checkpoint durability comes from the temp-file +
    # atomic-rename commit protocol (a torn save is discarded wholesale,
    # never salvaged), so the per-cluster recovery framing would only add
    # bytes that no reader CRC covers — without it, every byte of a
    # committed checkpoint is checksummed and a flip is always detected
    options = options or WriteOptions(
        codec="zlib", level=1, cluster_bytes=32 * 1024 * 1024, journal=False
    )
    leaves, treedef = _flatten_with_names(tree)
    manifest = _build_manifest(leaves, metadata)
    arrays = _host_arrays(leaves)
    units = _work_units(arrays, row_block_bytes)

    writer = ParallelWriter(CKPT_SCHEMA, path, options)

    # manifest entry (param_id = -1) goes in first
    mctx = writer.create_fill_context()
    mctx.fill_batch(_entry_batch([_manifest_entry(manifest)]))
    mctx.flush_cluster()

    def worker(widx: int):
        ctx = writer.create_fill_context()
        batch: List[Dict] = []
        for u, unit in enumerate(units):
            if u % n_writers != widx:
                continue
            batch.append(_unit_entry(arrays, u, unit))
            if sum(len(e["data"]) for e in batch) >= row_block_bytes:
                ctx.fill_batch(_entry_batch(batch))
                batch = []
        if batch:
            ctx.fill_batch(_entry_batch(batch))
        ctx.close()

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    writer.close()
    return writer.stats.as_dict()


def save_checkpoint_mp(
    path: str,
    tree,
    n_processes: int = 2,
    row_block_bytes: int = 4 * 1024 * 1024,
    options: Optional[WriteOptions] = None,
    metadata: Optional[Dict] = None,
    mp_context: str = "spawn",
    crash_worker: Optional[int] = None,
    crash_after_units: int = 1,
) -> Dict:
    """N-**process** sharded save into ONE container file.

    The real-deployment shape of :func:`save_checkpoint`: each writer is
    a separate OS process joining the shared file through the side-car
    extent log (DESIGN.md §8.6) instead of a thread sharing the in-process
    reserve lock.  The parent acts as coordinator — it writes the manifest
    cluster through an in-process participant, hands each child its
    round-robin share of work units (pickled host arrays), then runs the
    footer-assembly rendezvous.

    A worker killed mid-save (or ``crash_worker=i`` for tests: worker *i*
    hard-exits after ``crash_after_units`` entries) is fenced at lease
    expiry and the seal degrades gracefully: every fully journaled cluster
    is kept, the crash is recorded in ``footer.extra["mpw"]``, and the
    returned report has ``degraded=True`` so callers (CheckpointManager)
    can refuse to commit.  ``load_checkpoint(strict=False)`` restores the
    surviving parameters from such a file.

    Unlike the thread path, mp saves keep ``journal=True`` — the journal
    framing is what makes per-writer clusters independently salvageable.
    """
    options = options or WriteOptions(
        codec="zlib", level=1, cluster_bytes=32 * 1024 * 1024,
        lease_interval=2.0,
    )
    if not (options.buffered and options.journal):
        options = dataclasses.replace(options, buffered=True, journal=True)

    leaves, treedef = _flatten_with_names(tree)
    manifest = _build_manifest(leaves, metadata)
    arrays = _host_arrays(leaves)
    units = _work_units(arrays, row_block_bytes)

    # Round-robin shards, materialized as picklable entry dicts.  In a
    # real multi-host job each process owns its addressable shards and no
    # bytes cross processes; here the parent holds the whole tree, so the
    # hand-off is the pickle through the spawn pipe.
    shards: List[List[Dict]] = [[] for _ in range(n_processes)]
    for u, unit in enumerate(units):
        shards[u % n_processes].append(_unit_entry(arrays, u, unit))

    # the with-block skips the rendezvous when the body raises, so a
    # parent-side failure doesn't stall on the straggler timeout
    with MultiWriterCoordinator(CKPT_SCHEMA, path, options) as coord:
        mw = coord.participant()
        mctx = mw.create_fill_context()
        mctx.fill_batch(_entry_batch([_manifest_entry(manifest)]))
        mctx.flush_cluster()
        mw.close()

        ctx = multiprocessing.get_context(mp_context)
        procs = []
        for i in range(n_processes):
            crash = crash_after_units if crash_worker == i else None
            p = ctx.Process(
                target=run_save_worker,
                args=(path, shards[i], row_block_bytes, options, crash),
            )
            p.start()
            procs.append(p)
        for p in procs:
            p.join()
        exitcodes = [p.exitcode for p in procs]

        report = coord.seal(expect_writers=1 + n_processes)

    report["worker_exitcodes"] = exitcodes
    report["degraded"] = bool(
        report["fenced"] or report["salvaged"] or report["abandoned"]
        or any(c != 0 for c in exitcodes)
    )
    return report


def load_checkpoint(path: str, target_tree=None, shardings=None,
                    strict: bool = True):
    """-> (tree, metadata).  Reassembles from any cluster layout.

    Entries that arrive before the manifest are buffered, not rejected —
    a salvaged multi-writer file's cluster order is the global reservation
    order, which can interleave worker data ahead of the manifest.

    ``strict=False`` tolerates an *incomplete* checkpoint (a degraded
    multi-writer seal after a worker crash): parameters with missing
    shards come back zero-filled and their names are listed under
    ``metadata["restore_missing"]``.  With ``strict=True`` (default) any
    gap raises ``IOError``.
    """
    reader = RNTJReader(path)
    manifest = None
    buffers: Dict[int, np.ndarray] = {}
    covered: Dict[int, int] = {}
    pending: List[Tuple[int, tuple, int, int, bytes]] = []

    def _apply(pid, shape, r0, r1, data):
        npdt = _np_dtype(manifest["dtypes"][pid])
        if pid not in buffers:
            # zeros (not empty) when gaps are tolerated: uncovered rows
            # must read as a defined value, not heap garbage
            alloc = np.empty if strict else np.zeros
            buffers[pid] = alloc(shape, npdt)
        piece = np.frombuffer(data, npdt)
        if buffers[pid].ndim:
            buffers[pid][r0:r1] = piece.reshape((r1 - r0,) + shape[1:])
            covered[pid] = covered.get(pid, 0) + (r1 - r0)
        else:
            buffers[pid] = piece.reshape(()).copy()
            covered[pid] = 1

    for ci in range(reader.n_clusters):
        for e in reader.iter_cluster_entries(ci):
            pid = int(e["param_id"])
            data = np.asarray(e["data"], np.uint8).tobytes()
            if pid == -1:
                manifest = json.loads(data)
                for args in pending:
                    _apply(*args)
                pending = []
                continue
            shape = tuple(int(s) for s in e["shape"])
            r0, r1 = int(e["row_start"]), int(e["row_end"])
            if manifest is None:
                pending.append((pid, shape, r0, r1, data))
            else:
                _apply(pid, shape, r0, r1, data)
    reader.close()
    if manifest is None:
        raise IOError("checkpoint has no manifest entry")

    missing: List[str] = []
    leaves = []
    for pid, name in enumerate(manifest["names"]):
        shape = tuple(int(s) for s in manifest["shapes"][pid])
        need = shape[0] if shape else 1
        if covered.get(pid, 0) < need:
            missing.append(name)
            if pid not in buffers:
                buffers[pid] = np.zeros(shape, _np_dtype(manifest["dtypes"][pid]))
        leaves.append(buffers[pid])
    if missing and strict:
        raise IOError(
            f"checkpoint incomplete: missing or partial params {missing}"
        )

    tree = _unflatten_by_names(manifest["names"], leaves, target_tree)
    if shardings is not None:
        tree = jax.tree_util.tree_map(jax.device_put, tree, shardings)
    meta = dict(manifest["metadata"])
    if missing:
        meta["restore_missing"] = missing
    return tree, meta


def _unflatten_by_names(names: List[str], leaves, target_tree=None):
    if target_tree is not None:
        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        by_name = dict(zip(names, leaves))
        ordered = [by_name[jax.tree_util.keystr(p)] for p, _ in flat]
        return jax.tree_util.tree_unflatten(treedef, ordered)
    # build nested dicts from keystr names like "['a']['b']"
    import re
    root: Dict = {}
    for name, leaf in zip(names, leaves):
        keys = re.findall(r"\['([^']*)'\]|\[(\d+)\]|\.([A-Za-z_]\w*)", name)
        keys = [k or i or a for k, i, a in keys]
        cur = root
        for k in keys[:-1]:
            cur = cur.setdefault(k, {})
        cur[keys[-1]] = leaf
    return root
