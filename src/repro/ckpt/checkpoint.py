"""Single-file parallel checkpointing of sharded pytrees.

This is the paper's technique applied to the checkpoint path of a training
framework: every (virtual) host serializes + compresses its parameter
shards into relocatable clusters of ONE RNT-J file in parallel — no
per-host file tree and no post-hoc merge step (contrast: Orbax/tensorstore
write per-host files = the paper's "independent files + merge" baseline).

Checkpoint schema (nested, variable length — exactly the data shape the
format exists for)::

    entry := { param_id:int32, shard_index:int32,
               shape:[int64], row_start:int64, row_end:int64,
               data:[uint8] }

Entry param_id == -1 carries the JSON manifest (tree structure, names,
dtypes, step metadata).  Restore is mesh-shape-agnostic: clusters are
self-describing, so any number of readers can re-partition them (elastic
restart across different host counts).
"""

from __future__ import annotations

import json
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import (
    Collection, ColumnBatch, Leaf, ParallelWriter, RNTJReader, Schema,
    WriteOptions,
)

CKPT_SCHEMA = Schema([
    Leaf("param_id", "int32"),
    Leaf("shard_index", "int32"),
    Collection("shape", Leaf("_0", "int64")),
    Leaf("row_start", "int64"),
    Leaf("row_end", "int64"),
    Collection("data", Leaf("_0", "uint8")),
])

def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:  # bfloat16 etc. live in ml_dtypes
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_names(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out.append((name, leaf))
    return out, treedef


def _entry_batch(entries: List[Dict]) -> ColumnBatch:
    n = len(entries)
    by_path = {
        "param_id": np.array([e["param_id"] for e in entries], np.int32),
        "shard_index": np.array([e["shard_index"] for e in entries], np.int32),
        "shape": np.array([len(e["shape"]) for e in entries], np.int64),
        "shape._0": np.concatenate(
            [np.asarray(e["shape"], np.int64) for e in entries]
        ) if entries else np.empty(0, np.int64),
        "row_start": np.array([e["row_start"] for e in entries], np.int64),
        "row_end": np.array([e["row_end"] for e in entries], np.int64),
        "data": np.array([len(e["data"]) for e in entries], np.int64),
        "data._0": np.concatenate(
            [np.frombuffer(e["data"], np.uint8) for e in entries]
        ) if entries else np.empty(0, np.uint8),
    }
    return ColumnBatch.from_arrays(CKPT_SCHEMA, n, by_path)


def save_checkpoint(
    path: str,
    tree,
    n_writers: int = 4,
    row_block_bytes: int = 4 * 1024 * 1024,
    options: Optional[WriteOptions] = None,
    metadata: Optional[Dict] = None,
) -> Dict:
    """Parallel single-file save.

    ``n_writers`` simulates hosts: work (leaf row-blocks) is partitioned
    round-robin; each writer thread owns a fill context and commits its
    clusters through the shared reserve+metadata critical section.  In a
    real multi-host deployment each jax process runs one writer over its
    addressable shards and the critical section is the coordinator's
    extent ledger (DESIGN.md §3.2).
    """
    # journal=False: checkpoint durability comes from the temp-file +
    # atomic-rename commit protocol (a torn save is discarded wholesale,
    # never salvaged), so the per-cluster recovery framing would only add
    # bytes that no reader CRC covers — without it, every byte of a
    # committed checkpoint is checksummed and a flip is always detected
    options = options or WriteOptions(
        codec="zlib", level=1, cluster_bytes=32 * 1024 * 1024, journal=False
    )
    leaves, treedef = _flatten_with_names(tree)
    manifest = {
        "names": [n for n, _ in leaves],
        "dtypes": [str(l.dtype) for _, l in leaves],
        "shapes": [list(np.shape(l)) for _, l in leaves],
        "treedef": None,  # reconstructed from names at load
        "metadata": metadata or {},
    }

    # Work units: (param_id, row range) blocks so large tensors spread
    # across writers; every unit is independent (paper §1's reorderable rows).
    units: List[Tuple[int, int, int]] = []
    for pid, (_, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        rows = arr.shape[0] if arr.ndim else 1
        row_bytes = max(1, arr.nbytes // max(rows, 1))
        block = max(1, row_block_bytes // row_bytes)
        start = 0
        while start < rows or (rows == 0 and start == 0):
            end = min(rows, start + block)
            units.append((pid, start, end))
            if end >= rows:
                break
            start = end

    writer = ParallelWriter(CKPT_SCHEMA, path, options)

    # manifest entry (param_id = -1) goes in first
    mctx = writer.create_fill_context()
    mctx.fill_batch(_entry_batch([{
        "param_id": -1, "shard_index": 0, "shape": [],
        "row_start": 0, "row_end": 0,
        "data": json.dumps(manifest).encode(),
    }]))
    mctx.flush_cluster()

    def _host(l):
        a = np.asarray(l)
        # ascontiguousarray promotes 0-d to 1-d; keep true rank
        return np.ascontiguousarray(a) if a.ndim else a

    arrays = [_host(l) for _, l in leaves]

    def worker(widx: int):
        ctx = writer.create_fill_context()
        batch: List[Dict] = []
        for u, (pid, r0, r1) in enumerate(units):
            if u % n_writers != widx:
                continue
            arr = arrays[pid]
            piece = arr[r0:r1] if arr.ndim else arr
            batch.append({
                "param_id": pid, "shard_index": u,
                "shape": list(arr.shape),
                "row_start": r0, "row_end": r1,
                "data": piece.tobytes(),
            })
            if sum(len(e["data"]) for e in batch) >= row_block_bytes:
                ctx.fill_batch(_entry_batch(batch))
                batch = []
        if batch:
            ctx.fill_batch(_entry_batch(batch))
        ctx.close()

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    writer.close()
    return writer.stats.as_dict()


def load_checkpoint(path: str, target_tree=None, shardings=None):
    """-> (tree, metadata).  Reassembles from any cluster layout."""
    reader = RNTJReader(path)
    manifest = None
    buffers: Dict[int, np.ndarray] = {}

    for ci in range(reader.n_clusters):
        for e in reader.iter_cluster_entries(ci):
            pid = int(e["param_id"])
            data = np.asarray(e["data"], np.uint8).tobytes()
            if pid == -1:
                manifest = json.loads(data)
                continue
            if manifest is None:
                raise IOError("manifest entry missing or out of order")
            dtype = manifest["dtypes"][pid]
            shape = tuple(int(s) for s in e["shape"])
            npdt = _np_dtype(dtype)
            if pid not in buffers:
                buffers[pid] = np.empty(shape, npdt)
            r0, r1 = int(e["row_start"]), int(e["row_end"])
            piece = np.frombuffer(data, npdt)
            if buffers[pid].ndim:
                buffers[pid][r0:r1] = piece.reshape((r1 - r0,) + shape[1:])
            else:
                buffers[pid] = piece.reshape(()).copy()
    reader.close()

    # Return numpy arrays: dtypes survive exactly (jnp.asarray would
    # silently downcast int64 without x64); jit/device_put convert lazily.
    leaves = [buffers[pid] for pid in range(len(manifest["names"]))]

    tree = _unflatten_by_names(manifest["names"], leaves, target_tree)
    if shardings is not None:
        tree = jax.tree_util.tree_map(jax.device_put, tree, shardings)
    return tree, manifest["metadata"]


def _unflatten_by_names(names: List[str], leaves, target_tree=None):
    if target_tree is not None:
        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        by_name = dict(zip(names, leaves))
        ordered = [by_name[jax.tree_util.keystr(p)] for p, _ in flat]
        return jax.tree_util.tree_unflatten(treedef, ordered)
    # build nested dicts from keystr names like "['a']['b']"
    import re
    root: Dict = {}
    for name, leaf in zip(names, leaves):
        keys = re.findall(r"\['([^']*)'\]|\[(\d+)\]|\.([A-Za-z_]\w*)", name)
        keys = [k or i or a for k, i, a in keys]
        cur = root
        for k in keys[:-1]:
            cur = cur.setdefault(k, {})
        cur[keys[-1]] = leaf
    return root
