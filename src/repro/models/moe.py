"""Mixture-of-Experts FFN: top-k routing with capacity-factor dispatch.

GShard/Switch-style einsum dispatch — the TPU-native MoE formulation:
tokens are routed into per-expert capacity buckets with one-hot dispatch
tensors so all expert compute is dense matmul (MXU) and the expert axis
shards over the ``ep``(=model) mesh axis; GSPMD turns the dispatch einsums
into all-to-alls.

Supports Mixtral (8e top-2) and DeepSeekMoE (fine-grained 64e top-6 + 2
shared experts that every token uses).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard

from .layers import cdtype, dense_init, pdtype


def moe_init(rng, cfg: ArchConfig) -> Dict:
    m = cfg.moe
    d, f = cfg.d_model, cfg.d_ff
    dt = pdtype(cfg)
    ks = jax.random.split(rng, 5)

    def expert_bank(k, n):
        k1, k2, k3 = jax.random.split(k, 3)
        scale = 1.0 / np.sqrt(d)
        return {
            "w_gate": (jax.random.normal(k1, (n, d, f)) * scale).astype(dt),
            "w_up": (jax.random.normal(k2, (n, d, f)) * scale).astype(dt),
            "w_down": (jax.random.normal(k3, (n, f, d)) / np.sqrt(f)).astype(dt),
        }

    p = {
        "router": dense_init(ks[0], d, m.n_experts, dt),
        "experts": expert_bank(ks[1], m.n_experts),
    }
    if m.n_shared:
        p["shared"] = expert_bank(ks[2], m.n_shared)
    return p


def _capacity(group_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    cap = int(np.ceil(m.top_k * group_tokens * m.capacity_factor / m.n_experts))
    return max(4, ((cap + 3) // 4) * 4)  # pad to multiple of 4 for layout


def moe_apply(params: Dict, x: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, D) -> (y, aux_loss).

    GShard-style grouped dispatch: each sequence is a routing group, so
    the dispatch one-hots are (B, T, E, C) with C = k·T·cf/E — dispatch
    einsum cost stays a small fraction of expert compute (a single global
    group would make dispatch O(tokens²)).  FLOPs scale with
    top_k·capacity_factor, not n_experts (MODEL_FLOPS 6·N_active·D).
    """
    m = cfg.moe
    dt = cdtype(cfg)
    b, t, d = x.shape
    xt = x.astype(dt)
    xt = shard(xt, "dp", None, None)

    # --- routing (f32 for numerics) ---
    logits = jnp.einsum("btd,de->bte", xt, params["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)     # (B,T,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)             # (B,T,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # --- capacity-bucket dispatch, per group (GShard) ---
    cap = _capacity(t, cfg)
    onehot = jax.nn.one_hot(gate_idx, m.n_experts, dtype=jnp.float32)  # (B,T,K,E)
    # position of each (token, k) within its expert's bucket, per group
    flat = onehot.reshape(b, t * m.top_k, m.n_experts)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - 1.0).reshape(
        b, t, m.top_k, m.n_experts)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)                   # (B,T,K)
    keep = pos < cap                                                 # capacity drop
    gate_vals = gate_vals * keep.astype(jnp.float32)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    masked = onehot * keep[..., None].astype(jnp.float32)
    dispatch = jnp.einsum("btke,btkc->btec", masked, pos_oh)
    combine = jnp.einsum("btk,btke,btkc->btec", gate_vals, onehot, pos_oh)
    dispatch = shard(dispatch.astype(dt), "dp", None, "ep", "ep2")
    combine = shard(combine.astype(dt), "dp", None, "ep", "ep2")

    # --- expert compute (dense, expert axis sharded over ep) ---
    xe = jnp.einsum("btec,btd->ebcd", dispatch, xt)                  # (E,B,C,D)
    xe = shard(xe, "ep", None, "ep2", None)
    we = params["experts"]
    g = jax.nn.silu(jnp.einsum("ebcd,edf->ebcf", xe, we["w_gate"].astype(dt)))
    u = jnp.einsum("ebcd,edf->ebcf", xe, we["w_up"].astype(dt))
    h = shard(g * u, "ep", None, "ep2", None)
    ye = jnp.einsum("ebcf,efd->ebcd", h, we["w_down"].astype(dt))
    ye = shard(ye, "ep", None, "ep2", None)
    y = jnp.einsum("btec,ebcd->btd", combine, ye)                    # (B,T,D)

    # --- shared experts (always-on) ---
    if m.n_shared:
        ws = params["shared"]
        gs = jax.nn.silu(jnp.einsum("btd,sdf->btsf", xt, ws["w_gate"].astype(dt)))
        us = jnp.einsum("btd,sdf->btsf", xt, ws["w_up"].astype(dt))
        y = y + jnp.einsum("btsf,sfd->btd", gs * us, ws["w_down"].astype(dt))

    # --- load-balancing aux loss (Switch) ---
    me = jnp.mean(probs, axis=(0, 1))                                # (E,)
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))              # (E,)
    aux = m.n_experts * jnp.sum(me * ce)

    return shard(y, "dp", "sp", None), aux.astype(jnp.float32)
