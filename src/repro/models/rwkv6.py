"""RWKV-6 (Finch) block: data-dependent-decay time mix + channel mix.

Faithful structure per arXiv:2404.05892: token-shift with data-dependent
linear interpolation (low-rank "ddlerp"), low-rank data-dependent decay w,
the wkv6 recurrence (via repro.kernels.ops.rwkv6 — Pallas chunked kernel on
TPU), per-head group norm, output gate; squared-ReLU channel mix.

State for decode: (wkv state (B,H,Dk,Dv), time-mix shift (B,D),
channel-mix shift (B,D)).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.kernels import ops
from repro.kernels import ref as kref

from .layers import cdtype, dense_init, pdtype, rms_norm

DDLERP_RANK = 32
DECAY_RANK = 64


def rwkv6_block_init(rng, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    h = d // hd
    dt = pdtype(cfg)
    ks = jax.random.split(rng, 16)
    p = {
        # time mix
        "mu_x": (jnp.ones((5, d)) * 0.5).astype(dt),   # base lerp for w,k,v,r,g
        "ddlerp_a": dense_init(ks[0], d, 5 * DDLERP_RANK, dt),
        "ddlerp_b": dense_init(ks[1], 5 * DDLERP_RANK, 5 * d, dt, scale=0.01),
        "w_decay_a": dense_init(ks[2], d, DECAY_RANK, dt),
        "w_decay_b": dense_init(ks[3], DECAY_RANK, d, dt, scale=0.01),
        "decay_base": (jnp.zeros((d,)) - 5.0).astype(dt),
        "wr": dense_init(ks[4], d, d, dt),
        "wk": dense_init(ks[5], d, d, dt),
        "wv": dense_init(ks[6], d, d, dt),
        "wg": dense_init(ks[7], d, d, dt),
        "wo": dense_init(ks[8], d, d, dt),
        "u": (jax.random.normal(ks[9], (h, hd)) * 0.3).astype(dt),
        "ln_x": jnp.ones((d,), dt),
        # channel mix
        "mu_ffn": (jnp.ones((2, d)) * 0.5).astype(dt),
        "wk_ffn": dense_init(ks[10], d, cfg.d_ff, dt),
        "wv_ffn": dense_init(ks[11], cfg.d_ff, d, dt),
        "wr_ffn": dense_init(ks[12], d, d, dt),
        # norms
        "norm1": jnp.ones((d,), dt),
        "norm2": jnp.ones((d,), dt),
    }
    return p


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x: (B,T,D); prev: (B,D) last token of previous chunk -> shifted x."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _time_mix(p: Dict, x: jax.Array, xs: jax.Array, cfg: ArchConfig):
    """Compute r,k,v,g,w from x and its shifted version xs."""
    dt = x.dtype
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    h = d // hd
    b, t, _ = x.shape
    delta = xs - x
    # data-dependent lerp (low rank, 5 ways: w,k,v,r,g)
    base = x + delta * p["mu_x"].astype(dt)[:, None, None]            # (5,B,T,D)
    lora = jnp.tanh(jnp.einsum("btd,dr->btr", delta, p["ddlerp_a"].astype(dt)))
    mix = jnp.einsum("btr,re->bte", lora, p["ddlerp_b"].astype(dt))   # (B,T,5D)
    mix = mix.reshape(b, t, 5, d).transpose(2, 0, 1, 3)
    xw, xk, xv, xr, xg = tuple(base[i] + mix[i] for i in range(5))

    r = jnp.einsum("btd,de->bte", xr, p["wr"].astype(dt))
    k = jnp.einsum("btd,de->bte", xk, p["wk"].astype(dt))
    v = jnp.einsum("btd,de->bte", xv, p["wv"].astype(dt))
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["wg"].astype(dt)))
    decay = p["decay_base"].astype(jnp.float32) + jnp.einsum(
        "btr,rd->btd",
        jnp.tanh(jnp.einsum("btd,dr->btr", xw, p["w_decay_a"].astype(dt))),
        p["w_decay_b"].astype(dt),
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay))                                       # (B,T,D) in (0,1)

    def heads(z, dim):
        return z.reshape(b, t, h, dim).transpose(0, 2, 1, 3)           # (B,H,T,·)

    return (heads(r, hd), heads(k, hd), heads(v, hd),
            heads(w.astype(dt), hd), g)


def rwkv6_block_apply(p: Dict, x: jax.Array, cfg: ArchConfig,
                      positions=None) -> jax.Array:
    dt = cdtype(cfg)
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    h = d // hd
    b, t, _ = x.shape
    x = x.astype(dt)

    # ---- time mix ----
    xn = rms_norm(x, p["norm1"], cfg.norm_eps)
    xn = shard(xn, "dp", "sp", None)
    prev = jnp.zeros((b, d), dt)
    r, k, v, w, g = _time_mix(p, xn, _token_shift(xn, prev), cfg)
    r = shard(r, "dp", "tp", None, None)
    k = shard(k, "dp", "tp", None, None)
    v = shard(v, "dp", "tp", None, None)
    o, _ = ops.rwkv6(r, k, v, w, p["u"].astype(dt), chunk=cfg.ssm.chunk)  # (B,H,T,hd)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    o = rms_norm(o, p["ln_x"], cfg.norm_eps) * g
    x = x + jnp.einsum("btd,de->bte", o, p["wo"].astype(dt))

    # ---- channel mix ----
    xn = rms_norm(x, p["norm2"], cfg.norm_eps)
    prev = jnp.zeros((b, d), dt)
    xs = _token_shift(xn, prev)
    mu = p["mu_ffn"].astype(dt)
    xk = xn + (xs - xn) * mu[0]
    xr = xn + (xs - xn) * mu[1]
    kf = jnp.einsum("btd,df->btf", xk, p["wk_ffn"].astype(dt))
    kf = shard(jnp.square(jax.nn.relu(kf)), "dp", None, "tp")
    vf = jnp.einsum("btf,fd->btd", kf, p["wv_ffn"].astype(dt))
    rf = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr_ffn"].astype(dt)))
    x = x + rf * vf
    return shard(x, "dp", "sp", None)


# ---------------------------------------------------------------------------
# decode (stateful single token)


def rwkv6_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Dict:
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    h = d // hd
    return {
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((batch, d), dtype),
        "shift_cm": jnp.zeros((batch, d), dtype),
    }


def rwkv6_block_decode(p: Dict, x: jax.Array, cfg: ArchConfig,
                       cache: Dict, pos=None) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, D) one token; O(1) state update (long_500k path)."""
    dt = cdtype(cfg)
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    h = d // hd
    b = x.shape[0]
    x = x.astype(dt)

    xn = rms_norm(x, p["norm1"], cfg.norm_eps)
    xs = cache["shift_tm"][:, None]
    r, k, v, w, g = _time_mix(p, xn, xs, cfg)
    out, new_state = kref.rwkv6_decode_ref(
        r[:, :, 0], k[:, :, 0], v[:, :, 0], w[:, :, 0],
        p["u"].astype(dt), cache["wkv"],
    )
    o = out.reshape(b, 1, d).astype(dt)
    o = rms_norm(o, p["ln_x"], cfg.norm_eps) * g
    x = x + jnp.einsum("btd,de->bte", o, p["wo"].astype(dt))
    new_shift_tm = xn[:, 0]

    xn2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    xs2 = cache["shift_cm"][:, None]
    mu = p["mu_ffn"].astype(dt)
    xk = xn2 + (xs2 - xn2) * mu[0]
    xr = xn2 + (xs2 - xn2) * mu[1]
    kf = jnp.square(jax.nn.relu(
        jnp.einsum("btd,df->btf", xk, p["wk_ffn"].astype(dt))))
    vf = jnp.einsum("btf,fd->btd", kf, p["wv_ffn"].astype(dt))
    rf = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr_ffn"].astype(dt)))
    x = x + rf * vf
    return x, {
        "wkv": new_state,
        "shift_tm": new_shift_tm,
        "shift_cm": xn2[:, 0],
    }
