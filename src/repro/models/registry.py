"""Model registry: ArchConfig -> ModelBundle (init/loss/prefill/decode).

Also provides ``input_specs`` — ShapeDtypeStruct stand-ins for every model
input of a given (arch x shape-cell), the pattern the multi-pod dry-run
lowers against (no allocation).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import get_arch
from repro.configs.base import ArchConfig, ShapeCell

from . import backbone as B


@dataclass
class ModelBundle:
    cfg: ArchConfig

    def init(self, rng) -> Dict:
        return B.init_params(rng, self.cfg)

    def loss(self, params, batch) -> Tuple[jax.Array, Dict]:
        return B.loss_fn(params, batch, self.cfg)

    def forward(self, params, tokens):
        return B.forward(params, tokens, self.cfg)

    def prefill(self, params, tokens, max_len: int, cache_dtype=None):
        return B.prefill(params, tokens, self.cfg, max_len, cache_dtype)

    def init_cache(self, batch: int, max_len: int, dtype=None):
        return B.init_cache(self.cfg, batch, max_len, dtype)

    def decode_step(self, params, tokens, cache, pos):
        return B.decode_step(params, tokens, cache, pos, self.cfg)

    # -- dry-run specs -------------------------------------------------------

    def param_shapes(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def _token_shape(self, batch: int, seq: int) -> Tuple[int, ...]:
        if self.cfg.n_codebooks > 1:
            return (batch, seq, self.cfg.n_codebooks)
        return (batch, seq)

    def input_specs(self, cell: ShapeCell) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for the cell's step function inputs.

        The modality frontends of [vlm]/[audio] archs are stubs: specs are
        precomputed token ids (chameleon VQ codes / EnCodec codebook codes).
        """
        i32 = jnp.int32
        if cell.kind == "train":
            return {
                "tokens": jax.ShapeDtypeStruct(
                    self._token_shape(cell.global_batch, cell.seq_len), i32),
                "labels": jax.ShapeDtypeStruct(
                    self._token_shape(cell.global_batch, cell.seq_len), i32),
            }
        if cell.kind == "prefill":
            return {
                "tokens": jax.ShapeDtypeStruct(
                    self._token_shape(cell.global_batch, cell.seq_len), i32),
            }
        # decode: one new token against a cache of cell.seq_len
        cache_shapes = jax.eval_shape(
            lambda: self.init_cache(cell.global_batch, cell.seq_len)
        )
        return {
            "tokens": jax.ShapeDtypeStruct(
                self._token_shape(cell.global_batch, 1), i32),
            "cache": cache_shapes,
            "pos": jax.ShapeDtypeStruct((cell.global_batch,), i32),
        }

    def runnable(self, cell: ShapeCell) -> Tuple[bool, str]:
        """Is this (arch x cell) runnable? long_500k needs sub-quadratic."""
        if cell.name == "long_500k" and not self.cfg.sub_quadratic:
            return False, "SKIP(full-attn): 500k dense decode cache unbounded"
        return True, ""


def build(cfg_or_name) -> ModelBundle:
    cfg = cfg_or_name if isinstance(cfg_or_name, ArchConfig) else get_arch(cfg_or_name)
    return ModelBundle(cfg)


def make_batch(bundle: ModelBundle, rng: np.random.Generator, batch: int,
               seq: int) -> Dict[str, jax.Array]:
    """Random token batch for smoke tests / examples."""
    shape = bundle._token_shape(batch, seq)
    toks = rng.integers(0, bundle.cfg.vocab_size, shape).astype(np.int32)
    labels = np.roll(toks, -1, axis=1)
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
