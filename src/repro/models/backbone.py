"""Unified decoder backbone for all ten assigned architectures.

Layer stack is a ``jax.lax.scan`` over stacked per-layer params (O(1) HLO
size for 95-layer models, remat-compatible); the zamba2 hybrid unrolls its
9 groups of (6 mamba layers -> shared attention block).

Three entry points per model (see registry.ModelBundle):
  * ``loss``        — next-token CE for train_4k
  * ``prefill``     — full-sequence forward + KV/state cache build (prefill_32k)
  * ``decode_step`` — one token against the cache (decode_32k / long_500k)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.kernels import ops, ref as kref

from . import attention as A
from . import mamba2 as M2
from . import moe as MOE
from . import rwkv6 as R6
from .layers import (
    cdtype, cross_entropy_loss, embed_tokens, embedding_init, lm_logits,
    mlp_apply, mlp_init, pdtype, rms_norm,
)

# ---------------------------------------------------------------------------
# per-layer block: init / apply / prefill / decode


def _is_attn_block(cfg: ArchConfig) -> bool:
    return cfg.family in ("dense", "vlm", "audio", "moe")


def block_init(rng, cfg: ArchConfig) -> Dict:
    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        return R6.rwkv6_block_init(rng, cfg)
    if cfg.family == "hybrid" or (cfg.ssm and cfg.ssm.kind == "mamba2"):
        return M2.mamba2_block_init(rng, cfg)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    dt = pdtype(cfg)
    p = {
        "norm1": jnp.ones((cfg.d_model,), dt),
        "norm2": jnp.ones((cfg.d_model,), dt),
        "attn": A.mla_init(k1, cfg) if cfg.attention == "mla" else A.gqa_init(k1, cfg),
    }
    if cfg.moe is not None:
        p["moe"] = MOE.moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg)
    return p


def block_apply(p: Dict, x: jax.Array, cfg: ArchConfig,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        return R6.rwkv6_block_apply(p, x, cfg, positions), aux
    if cfg.family == "hybrid":
        return M2.mamba2_block_apply(p, x, cfg, positions), aux
    xn = rms_norm(x, p["norm1"], cfg.norm_eps)
    attn = A.mla_apply if cfg.attention == "mla" else A.gqa_apply
    x = x + attn(p["attn"], xn, cfg, positions)
    xn = rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = MOE.moe_apply(p["moe"], xn, cfg)
    else:
        y = mlp_apply(p["mlp"], xn, cfg)
    return shard(x + y, "dp", "sp", None), aux


def block_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Dict:
    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        return R6.rwkv6_init_cache(cfg, batch, max_len, dtype)
    if cfg.family == "hybrid":
        return M2.mamba2_init_cache(cfg, batch, max_len, dtype)
    if cfg.attention == "mla":
        return A.mla_init_cache(cfg, batch, max_len, dtype)
    return A.gqa_init_cache(cfg, batch, max_len, dtype)


def block_decode(p: Dict, x: jax.Array, cfg: ArchConfig, cache: Dict,
                 pos: jax.Array) -> Tuple[jax.Array, Dict]:
    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        return R6.rwkv6_block_decode(p, x, cfg, cache, pos)
    if cfg.family == "hybrid":
        return M2.mamba2_block_decode(p, x, cfg, cache, pos)
    xn = rms_norm(x, p["norm1"], cfg.norm_eps)
    dec = A.mla_apply_decode if cfg.attention == "mla" else A.gqa_apply_decode
    y, new_cache = dec(p["attn"], xn, cfg, cache, pos)
    x = x + y
    xn = rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, _ = MOE.moe_apply(p["moe"], xn, cfg)
    else:
        y = mlp_apply(p["mlp"], xn, cfg)
    return x + y, new_cache


def block_prefill(p: Dict, x: jax.Array, cfg: ArchConfig, positions: jax.Array,
                  max_len: int, dtype) -> Tuple[jax.Array, Dict]:
    """Full-sequence forward that also builds this layer's decode cache."""
    b, s, _ = x.shape
    if cfg.family == "ssm" and cfg.ssm.kind == "rwkv6":
        return _rwkv6_prefill(p, x, cfg)
    if cfg.family == "hybrid":
        return _mamba2_prefill(p, x, cfg)
    if cfg.attention == "mla":
        return _mla_prefill(p, x, cfg, positions, max_len, dtype)
    return _gqa_prefill(p, x, cfg, positions, max_len, dtype)


def _finish_block(p, x, attn_out, cfg):
    x = x + attn_out
    xn = rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, _ = MOE.moe_apply(p["moe"], xn, cfg)
    else:
        y = mlp_apply(p["mlp"], xn, cfg)
    return x + y


def _gqa_prefill(p, x, cfg, positions, max_len, dtype):
    b, s, _ = x.shape
    xn = rms_norm(x, p["norm1"], cfg.norm_eps)
    q, k, v = A._qkv(p["attn"], xn, cfg, positions)
    y = ops.flash_attention(q, k, v, causal=True, window=cfg.window,
                            impl=cfg.attn_impl)
    y = y.swapaxes(1, 2).reshape(b, s, cfg.n_heads * cfg.resolved_head_dim)
    attn_out = jnp.einsum("btk,kd->btd", y, p["attn"]["wo"].astype(y.dtype))
    x = _finish_block(p, x, attn_out, cfg)

    cache = A.gqa_init_cache(cfg, b, max_len, dtype)
    cache_len = cache["k"].shape[2]
    if cache_len >= s:
        kc = jnp.pad(k, ((0, 0), (0, 0), (0, cache_len - s), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, 0), (0, cache_len - s), (0, 0)))
    else:
        # ring buffer (SWA): keep the last cache_len tokens at slots pos % len
        last_pos = np.arange(0, 0) if False else jnp.arange(s - cache_len, s)
        slots = last_pos % cache_len
        kc = jnp.zeros_like(cache["k"]).at[:, :, slots].set(k[:, :, -cache_len:])
        vc = jnp.zeros_like(cache["v"]).at[:, :, slots].set(v[:, :, -cache_len:])
    return x, {"k": kc.astype(dtype), "v": vc.astype(dtype)}


def _mla_prefill(p, x, cfg, positions, max_len, dtype):
    b, s, _ = x.shape
    xn = rms_norm(x, p["norm1"], cfg.norm_eps)
    q_nope, q_rope, c, k_rope = A._mla_qckr(p["attn"], xn, cfg, positions)
    attn_out = A._mla_attend(p["attn"], q_nope, q_rope, c, k_rope, cfg)
    x = _finish_block(p, x, attn_out, cfg)
    pad = max_len - s
    cache = {
        "c": jnp.pad(c, ((0, 0), (0, pad), (0, 0))).astype(dtype),
        "k_rope": jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))).astype(dtype),
    }
    return x, cache


def _rwkv6_prefill(p, x, cfg):
    """Run the block via the state-returning ref path to seed decode."""
    dt = cdtype(cfg)
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    b, t, _ = x.shape
    x = x.astype(dt)
    xn = rms_norm(x, p["norm1"], cfg.norm_eps)
    prev = jnp.zeros((b, d), dt)
    r, k, v, w, g = R6._time_mix(p, xn, R6._token_shift(xn, prev), cfg)
    o, wkv_state = ops.rwkv6(r, k, v, w, p["u"].astype(dt), chunk=cfg.ssm.chunk)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d).astype(dt)
    o = rms_norm(o, p["ln_x"], cfg.norm_eps) * g
    x = x + jnp.einsum("btd,de->bte", o, p["wo"].astype(dt))
    shift_tm = xn[:, -1]

    xn2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    xs2 = R6._token_shift(xn2, jnp.zeros((b, d), dt))
    mu = p["mu_ffn"].astype(dt)
    xk = xn2 + (xs2 - xn2) * mu[0]
    xr = xn2 + (xs2 - xn2) * mu[1]
    kf = jnp.square(jax.nn.relu(
        jnp.einsum("btd,df->btf", xk, p["wk_ffn"].astype(dt))))
    vf = jnp.einsum("btf,fd->btd", kf, p["wv_ffn"].astype(dt))
    rf = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr_ffn"].astype(dt)))
    x = x + rf * vf
    return x, {"wkv": wkv_state, "shift_tm": shift_tm, "shift_cm": xn2[:, -1]}


def _mamba2_prefill(p, x, cfg):
    dt_ = cdtype(cfg)
    d_inner, h, n, pdim, kk = M2._dims(cfg)
    b, t, _ = x.shape
    x = x.astype(dt_)
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("btd,de->bte", xn, p["w_in"].astype(dt_))
    z, xr, B, C, dt_raw = M2._split_proj(zxbcdt, d_inner, n, h)
    xbc_pre = jnp.concatenate([xr, B, C], axis=-1)
    xbc = M2._causal_conv(xbc_pre, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    xr, B, C = (xbc[..., :d_inner], xbc[..., d_inner : d_inner + n],
                xbc[..., d_inner + n :])
    delta = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    Aa = -jnp.exp(p["A_log"].astype(jnp.float32))
    log_a = (delta * Aa).transpose(0, 2, 1)
    xh = xr.reshape(b, t, h, pdim).transpose(0, 2, 1, 3)
    xh = xh * delta.transpose(0, 2, 1)[..., None].astype(dt_)
    y, ssd_state = ops.mamba2(xh, log_a.astype(jnp.float32),
                              B.astype(jnp.float32), C.astype(jnp.float32),
                              chunk=cfg.ssm.chunk)
    y = y + p["D"].astype(y.dtype)[None, :, None, None] * xh
    y = y.transpose(0, 2, 1, 3).reshape(b, t, d_inner).astype(dt_)
    y = rms_norm(y, p["norm_gate"], cfg.norm_eps) * jax.nn.silu(z)
    out = x + jnp.einsum("bte,ed->btd", y, p["w_out"].astype(dt_))
    conv_state = xbc_pre[:, -(kk - 1):] if t >= kk - 1 else jnp.pad(
        xbc_pre, ((0, 0), (kk - 1 - t, 0), (0, 0)))
    return out, {"conv": conv_state, "ssd": ssd_state}


# ---------------------------------------------------------------------------
# model init


def init_params(rng, cfg: ArchConfig) -> Dict:
    k_embed, k_layers, k_shared = jax.random.split(rng, 3)
    params: Dict[str, Any] = {"embedding": embedding_init(k_embed, cfg)}
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: block_init(k, cfg))(layer_keys)
    if cfg.shared_attn_every:
        shared_cfg = _shared_attn_cfg(cfg)
        ks1, ks2 = jax.random.split(k_shared)
        params["shared_attn"] = {
            "norm1": jnp.ones((cfg.d_model,), pdtype(cfg)),
            "norm2": jnp.ones((cfg.d_model,), pdtype(cfg)),
            "attn": A.gqa_init(ks1, shared_cfg),
            "mlp": mlp_init(ks2, shared_cfg),
        }
    return params


def _shared_attn_cfg(cfg: ArchConfig) -> ArchConfig:
    """Zamba2's shared transformer block config (full attention + MLP)."""
    return cfg.with_(family="dense", attention="gqa", moe=None, ssm=None,
                     shared_attn_every=0)


def _shared_attn_apply(p, x, cfg, positions):
    sc = _shared_attn_cfg(cfg)
    xn = rms_norm(x, p["norm1"], sc.norm_eps)
    x = x + A.gqa_apply(p["attn"], xn, sc, positions)
    xn = rms_norm(x, p["norm2"], sc.norm_eps)
    return x + mlp_apply(p["mlp"], xn, sc)


def _shared_attn_decode(p, x, cfg, cache, pos, window: Optional[int]):
    sc = _shared_attn_cfg(cfg)
    if window is not None:
        sc = sc.with_(window=window)
    xn = rms_norm(x, p["norm1"], sc.norm_eps)
    y, new_cache = A.gqa_apply_decode(p["attn"], xn, sc, cache, pos)
    x = x + y
    xn = rms_norm(x, p["norm2"], sc.norm_eps)
    return x + mlp_apply(p["mlp"], xn, sc), new_cache


def _shared_attn_prefill(p, x, cfg, positions, max_len, dtype, window):
    sc = _shared_attn_cfg(cfg)
    if window is not None:
        sc = sc.with_(window=window)
    fake = {"norm1": p["norm1"], "norm2": p["norm2"], "attn": p["attn"],
            "mlp": p["mlp"]}
    return _gqa_prefill(fake, x, sc, positions, max_len, dtype)


# ---------------------------------------------------------------------------
# forward passes


def _layer_slice(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _run_stack(body, carry, stacked, cfg: ArchConfig):
    """scan-over-layers, or a python unroll when cfg.scan_layers=False.

    The unrolled form exists for the dry-run cost probes: XLA's
    HloCostAnalysis counts a while-loop body ONCE regardless of trip
    count, so true per-layer flops/bytes/collectives are extrapolated
    from small unrolled compiles (launch/dryrun.py).
    """
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, stacked)
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, _layer_slice(stacked, i))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _scan_layers(params, x, cfg: ArchConfig, positions):
    """(x, total_aux) after the layer stack."""

    def body(carry, layer_p):
        x, aux = carry
        x, a = block_apply(layer_p, x, cfg, positions)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if cfg.shared_attn_every:
        n_groups = cfg.n_layers // cfg.shared_attn_every
        aux = jnp.zeros((), jnp.float32)
        for g in range(n_groups):
            group_p = jax.tree_util.tree_map(
                lambda a, g=g: a[g * cfg.shared_attn_every:(g + 1) * cfg.shared_attn_every],
                params["layers"],
            )
            (x, aux), _ = _run_stack(body, (x, aux), group_p, cfg)
            x = _shared_attn_apply(params["shared_attn"], x, cfg, positions)
        return x, aux

    (x, aux), _ = _run_stack(
        body, (x, jnp.zeros((), jnp.float32)), params["layers"], cfg
    )
    return x, aux


def forward(params: Dict, tokens: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """tokens (B,S[,Q]) -> (logits, aux_loss)."""
    b, s = tokens.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed_tokens(params["embedding"], tokens, cfg)
    x, aux = _scan_layers(params, x, cfg, positions)
    return lm_logits(params["embedding"], x, cfg), aux


def loss_fn(params: Dict, batch: Dict, cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, batch["tokens"], cfg)
    ce = cross_entropy_loss(logits, batch["labels"])
    total = ce + 0.01 * aux
    return total, {"ce": ce, "aux": aux}


def prefill(params: Dict, tokens: jax.Array, cfg: ArchConfig,
            max_len: int, cache_dtype=None) -> Tuple[jax.Array, Any]:
    """-> (last-token logits (B,1,V[,Q]), stacked cache)."""
    cache_dtype = cache_dtype or cdtype(cfg)
    b, s = tokens.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed_tokens(params["embedding"], tokens, cfg)

    def body(x, layer_p):
        x, cache = block_prefill(layer_p, x, cfg, positions, max_len, cache_dtype)
        return x, cache

    if cfg.shared_attn_every:
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        window = cfg.window or _zamba_shared_window(max_len)
        caches, shared_caches = [], []
        for g in range(n_groups):
            group_p = jax.tree_util.tree_map(
                lambda a, g=g: a[g * every:(g + 1) * every], params["layers"])
            x, cache = _run_stack(body, x, group_p, cfg)
            caches.append(cache)
            x, sc = _shared_attn_prefill(params["shared_attn"], x, cfg,
                                         positions, max_len, cache_dtype, window)
            shared_caches.append(sc)
        cache = jax.tree_util.tree_map(
            lambda *cs: jnp.concatenate(cs, axis=0), *caches)
        shared = jax.tree_util.tree_map(
            lambda *cs: jnp.stack(cs, axis=0), *shared_caches)
        full_cache = {"layers": cache, "shared": shared}
    else:
        x, cache = _run_stack(body, x, params["layers"], cfg)
        full_cache = {"layers": cache}

    logits = lm_logits(params["embedding"], x[:, -1:], cfg)
    return logits, full_cache


def _zamba_shared_window(max_len: int) -> Optional[int]:
    """At long context the zamba2 shared-attn blocks run windowed (4096) to
    keep cache memory bounded — documented approximation (DESIGN.md §6)."""
    return 4096 if max_len > 65536 else None


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cdtype(cfg)

    def one(_):
        return block_init_cache(cfg, batch, max_len, dtype)

    stacked = jax.tree_util.tree_map(
        lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), one(None)
    )
    full = {"layers": stacked}
    if cfg.shared_attn_every:
        window = cfg.window or _zamba_shared_window(max_len)
        sc = _shared_attn_cfg(cfg)
        if window is not None:
            sc = sc.with_(window=window)
        n_groups = cfg.n_layers // cfg.shared_attn_every
        shared = A.gqa_init_cache(sc, batch, max_len, dtype)
        full["shared"] = jax.tree_util.tree_map(
            lambda x: jnp.zeros((n_groups,) + x.shape, x.dtype), shared)
    return full


def decode_step(params: Dict, tokens: jax.Array, cache, pos: jax.Array,
                cfg: ArchConfig) -> Tuple[jax.Array, Any]:
    """tokens (B,1[,Q]), pos (B,) -> (logits (B,1,V[,Q]), new cache)."""
    x = embed_tokens(params["embedding"], tokens, cfg)

    def body(x, xs):
        layer_p, layer_cache = xs
        x, new_cache = block_decode(layer_p, x, cfg, layer_cache, pos)
        return x, new_cache

    if cfg.shared_attn_every:
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        # ring semantics with window == cache_len are exact when the cache
        # was not truncated, and give the documented windowed behaviour when
        # it was (long_500k).
        window = int(jax.tree_util.tree_leaves(cache["shared"])[0].shape[3])
        new_layer_caches, new_shared = [], []
        for g in range(n_groups):
            group = jax.tree_util.tree_map(
                lambda a, g=g: a[g * every:(g + 1) * every], params["layers"])
            gcache = jax.tree_util.tree_map(
                lambda a, g=g: a[g * every:(g + 1) * every], cache["layers"])
            x, nc = _run_stack(body, x, (group, gcache), cfg)
            new_layer_caches.append(nc)
            scache = jax.tree_util.tree_map(lambda a, g=g: a[g], cache["shared"])
            x, nsc = _shared_attn_decode(params["shared_attn"], x, cfg,
                                         scache, pos, window)
            new_shared.append(nsc)
        new_cache = {
            "layers": jax.tree_util.tree_map(
                lambda *cs: jnp.concatenate(cs, axis=0), *new_layer_caches),
            "shared": jax.tree_util.tree_map(
                lambda *cs: jnp.stack(cs, axis=0), *new_shared),
        }
    else:
        x, nc = _run_stack(body, x, (params["layers"], cache["layers"]), cfg)
        new_cache = {"layers": nc}

    logits = lm_logits(params["embedding"], x, cfg)
    return logits, new_cache
