"""Model zoo: the ten assigned architectures on one unified backbone."""

from .registry import ModelBundle, build, make_batch

__all__ = ["ModelBundle", "build", "make_batch"]
