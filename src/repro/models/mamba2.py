"""Mamba-2 block (used standalone and as the Zamba2 backbone layer).

Structure per Mamba-2 (SSD): in_proj -> [z | x | B | C | dt]; short causal
conv over (x,B,C); SSD scan with scalar per-head decay (via
repro.kernels.ops.mamba2 — Pallas chunked kernel on TPU); gated RMSNorm;
out_proj.  Decode keeps a conv ring state and the (N,P) SSD state per head:
O(1) memory in sequence length (the long_500k path).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.kernels import ops
from repro.kernels import ref as kref

from .layers import cdtype, dense_init, pdtype, rms_norm


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.d_state, s.head_dim, s.conv_kernel


def mamba2_block_init(rng, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    d_inner, h, n, p_, k = _dims(cfg)
    dt = pdtype(cfg)
    ks = jax.random.split(rng, 6)
    conv_dim = d_inner + 2 * n
    return {
        "norm": jnp.ones((d,), dt),
        "w_in": dense_init(ks[0], d, 2 * d_inner + 2 * n + h, dt),
        "conv_w": (jax.random.normal(ks[1], (k, conv_dim)) / np.sqrt(k)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dt),   # per-head decay rate
        "dt_bias": jnp.zeros((h,), dt),
        "D": jnp.ones((h,), dt),
        "norm_gate": jnp.ones((d_inner,), dt),
        "w_out": dense_init(ks[2], d_inner, d, dt),
    }


def _split_proj(zxbcdt, d_inner, n, h):
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner : 2 * d_inner]
    B = zxbcdt[..., 2 * d_inner : 2 * d_inner + n]
    C = zxbcdt[..., 2 * d_inner + n : 2 * d_inner + 2 * n]
    dt_raw = zxbcdt[..., 2 * d_inner + 2 * n :]
    return z, x, B, C, dt_raw


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d, kernel k.  xbc: (B, T, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xbc.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def mamba2_block_apply(p: Dict, x_in: jax.Array, cfg: ArchConfig,
                       positions=None) -> jax.Array:
    dt_ = cdtype(cfg)
    d_inner, h, n, pdim, k = _dims(cfg)
    b, t, _ = x_in.shape
    x_in = x_in.astype(dt_)

    xn = rms_norm(x_in, p["norm"], cfg.norm_eps)
    xn = shard(xn, "dp", "sp", None)
    zxbcdt = jnp.einsum("btd,de->bte", xn, p["w_in"].astype(dt_))
    z, xr, B, C, dt_raw = _split_proj(zxbcdt, d_inner, n, h)
    xbc = _causal_conv(
        jnp.concatenate([xr, B, C], axis=-1), p["conv_w"].astype(dt_),
        p["conv_b"].astype(dt_),
    )
    xr, B, C = xbc[..., :d_inner], xbc[..., d_inner : d_inner + n], xbc[..., d_inner + n :]
    delta = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                            # (B,T,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,) negative
    log_a = (delta * A).transpose(0, 2, 1)                       # (B,H,T)
    xh = xr.reshape(b, t, h, pdim).transpose(0, 2, 1, 3)         # (B,H,T,P)
    xh = xh * delta.transpose(0, 2, 1)[..., None].astype(dt_)    # dt-scaled input
    xh = shard(xh, "dp", "tp", None, None)
    y, _ = ops.mamba2(xh, log_a.astype(jnp.float32), B.astype(jnp.float32),
                      C.astype(jnp.float32), chunk=cfg.ssm.chunk)  # (B,H,T,P)
    y = y + p["D"].astype(y.dtype)[None, :, None, None] * xh
    y = y.transpose(0, 2, 1, 3).reshape(b, t, d_inner).astype(dt_)
    y = rms_norm(y, p["norm_gate"], cfg.norm_eps) * jax.nn.silu(z)
    out = x_in + jnp.einsum("bte,ed->btd", y, p["w_out"].astype(dt_))
    return shard(out, "dp", "sp", None)


# ---------------------------------------------------------------------------
# decode


def mamba2_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Dict:
    d_inner, h, n, pdim, k = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "conv": jnp.zeros((batch, k - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, h, n, pdim), jnp.float32),
    }


def mamba2_block_decode(p: Dict, x_in: jax.Array, cfg: ArchConfig,
                        cache: Dict, pos=None) -> Tuple[jax.Array, Dict]:
    dt_ = cdtype(cfg)
    d_inner, h, n, pdim, k = _dims(cfg)
    b = x_in.shape[0]
    x_in = x_in.astype(dt_)

    xn = rms_norm(x_in, p["norm"], cfg.norm_eps)
    zxbcdt = jnp.einsum("btd,de->bte", xn, p["w_in"].astype(dt_))
    z, xr, B, C, dt_raw = _split_proj(zxbcdt, d_inner, n, h)
    xbc_new = jnp.concatenate([xr, B, C], axis=-1)               # (B,1,conv)
    conv_window = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B,k,conv)
    w = p["conv_w"].astype(dt_)
    out = jax.nn.silu(
        jnp.sum(conv_window * w[None], axis=1, keepdims=True)
        + p["conv_b"].astype(dt_)
    )
    xr, B, C = out[..., :d_inner], out[..., d_inner : d_inner + n], out[..., d_inner + n :]
    delta = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )[:, 0]                                                       # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    log_a = delta * A
    xh = xr.reshape(b, h, pdim) * delta[..., None].astype(dt_)
    y, new_ssd = kref.mamba2_decode_ref(
        xh.astype(jnp.float32), log_a, B[:, 0].astype(jnp.float32),
        C[:, 0].astype(jnp.float32), p["D"].astype(jnp.float32), cache["ssd"],
    )
    y = (y + 0.0).reshape(b, 1, d_inner).astype(dt_)
    y = rms_norm(y, p["norm_gate"], cfg.norm_eps) * jax.nn.silu(z)
    out_x = x_in + jnp.einsum("bte,ed->btd", y, p["w_out"].astype(dt_))
    return out_x, {"conv": conv_window[:, 1:], "ssd": new_ssd}
