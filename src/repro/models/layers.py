"""Shared model layers: norms, rope, MLPs, embeddings.

Pure-functional: params are nested dicts of jnp arrays; init functions
take an rng and the ArchConfig.  Activation sharding uses logical axes via
``repro.distributed.sharding.shard`` (no-op outside a mesh context).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard


def cdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(rng, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype):
    return (jax.random.normal(rng, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(ms + eps)) * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings


def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, D) with D even; positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs


def mlp_init(rng, cfg: ArchConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = pdtype(cfg)
    ks = jax.random.split(rng, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d, f, dt),
            "w_up": dense_init(ks[1], d, f, dt),
            "w_down": dense_init(ks[2], f, d, dt),
        }
    # plain gelu MLP (musicgen)
    return {
        "w_up": dense_init(ks[0], d, f, dt),
        "w_down": dense_init(ks[1], f, d, dt),
    }


def mlp_apply(params: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: (B, T, D).  Gated (swiglu/geglu) or plain-gelu MLP, TP on d_ff."""
    dt = cdtype(cfg)
    x = x.astype(dt)
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else (
            lambda u: jax.nn.gelu(u, approximate=True)
        )
        g = jnp.einsum("btd,df->btf", x, params["w_gate"].astype(dt))
        u = jnp.einsum("btd,df->btf", x, params["w_up"].astype(dt))
        h = act(g) * u
    else:
        u = jnp.einsum("btd,df->btf", x, params["w_up"].astype(dt))
        h = jax.nn.gelu(u, approximate=True)
    h = shard(h, "dp", None, "tp")
    out = jnp.einsum("btf,fd->btd", h, params["w_down"].astype(dt))
    return shard(out, "dp", "sp", None)


# ---------------------------------------------------------------------------
# embeddings / lm head


def embedding_init(rng, cfg: ArchConfig) -> Dict:
    dt = pdtype(cfg)
    ks = jax.random.split(rng, 2 + cfg.n_codebooks)
    p: Dict = {}
    if cfg.n_codebooks > 1:
        p["embed"] = jnp.stack(
            [embed_init(ks[i], cfg.vocab_size, cfg.d_model, dt)
             for i in range(cfg.n_codebooks)]
        )  # (Q, V, D)
    else:
        p["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks > 1:
            p["lm_head"] = jnp.stack(
                [dense_init(ks[1 + i], cfg.d_model, cfg.vocab_size, dt)
                 for i in range(cfg.n_codebooks)]
            )  # (Q, D, V)
        else:
            p["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size, dt)
    p["final_norm"] = jnp.ones((cfg.d_model,), dt)
    return p


def embed_tokens(params: Dict, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    """tokens: (B, T) int32, or (B, T, Q) for multi-codebook models."""
    dt = cdtype(cfg)
    emb = params["embed"].astype(dt)
    if cfg.n_codebooks > 1:
        # sum the codebook embeddings (musicgen delay-pattern backbone)
        x = sum(emb[q][tokens[..., q]] for q in range(cfg.n_codebooks))
    else:
        x = emb[tokens]
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)  # gemma embed scaling
    return shard(x, "dp", "sp", None)


def lm_logits(params: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    dt = cdtype(cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.n_codebooks > 1:
        heads = params["lm_head"].astype(dt)                 # (Q, D, V)
        logits = jnp.einsum("btd,qdv->btqv", x.astype(dt), heads)
        return shard(logits, "dp", None, None, "tp")
    w = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(dt)
    logits = jnp.einsum("btd,dv->btv", x.astype(dt), w)
    return shard(logits, "dp", None, "tp")


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       ignore_index: int = -100) -> jax.Array:
    """Mean token NLL in f32.  logits (..., V), labels (...)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = lse - gold
    mask = (labels != ignore_index).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
