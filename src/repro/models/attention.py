"""Attention mixers: GQA/MQA (+ sliding window, qk-norm) and MLA.

Each mixer exposes::

    init(rng, cfg)                          -> params
    apply(params, x, cfg, positions)        -> y                (train/prefill)
    init_cache(cfg, batch, max_len, dtype)  -> cache            (per layer)
    apply_decode(params, x, cfg, cache, pos)-> (y, new_cache)   (one token)

Caches are per-layer pytrees; the backbone stacks them along a leading
layer axis for the scan.  The attention math itself goes through
``repro.kernels.ops`` (Pallas on TPU, jnp oracle elsewhere).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard
from repro.kernels import ops

from .layers import apply_rope, cdtype, dense_init, pdtype, rms_norm


# ---------------------------------------------------------------------------
# GQA / MQA


def gqa_init(rng, cfg: ArchConfig) -> Dict:
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = pdtype(cfg)
    ks = jax.random.split(rng, 6)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dt),
        "wk": dense_init(ks[1], d, g * hd, dt),
        "wv": dense_init(ks[2], d, g * hd, dt),
        "wo": dense_init(ks[3], h * hd, d, dt, scale=1.0 / np.sqrt(h * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _qkv(params: Dict, x: jax.Array, cfg: ArchConfig, positions: jax.Array):
    dt = cdtype(cfg)
    b, t, _ = x.shape
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    x = x.astype(dt)
    q = jnp.einsum("btd,dk->btk", x, params["wq"].astype(dt)).reshape(b, t, h, hd)
    k = jnp.einsum("btd,dk->btk", x, params["wk"].astype(dt)).reshape(b, t, g, hd)
    v = jnp.einsum("btd,dk->btk", x, params["wv"].astype(dt)).reshape(b, t, g, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q.swapaxes(1, 2), positions[:, None], cfg.rope_theta)  # (B,H,T,hd)
    k = apply_rope(k.swapaxes(1, 2), positions[:, None], cfg.rope_theta)  # (B,G,T,hd)
    v = v.swapaxes(1, 2)
    q = shard(q, "dp", "tp", "sp_attn", None)
    k = shard(k, "dp", "tp_kv", None, None)
    v = shard(v, "dp", "tp_kv", None, None)
    return q, k, v


def gqa_apply(params: Dict, x: jax.Array, cfg: ArchConfig,
              positions: jax.Array) -> jax.Array:
    b, t, _ = x.shape
    q, k, v = _qkv(params, x, cfg, positions)
    y = ops.flash_attention(q, k, v, causal=True, window=cfg.window,
                            impl=cfg.attn_impl)
    y = shard(y, "dp", "tp", "sp_attn", None)
    y = y.swapaxes(1, 2).reshape(b, t, cfg.n_heads * cfg.resolved_head_dim)
    out = jnp.einsum("btk,kd->btd", y, params["wo"].astype(y.dtype))
    return shard(out, "dp", "sp", None)


def gqa_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Dict:
    g, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    cache_len = min(max_len, cfg.window) if cfg.window else max_len
    return {
        "k": jnp.zeros((batch, g, cache_len, hd), dtype),
        "v": jnp.zeros((batch, g, cache_len, hd), dtype),
    }


def gqa_apply_decode(params: Dict, x: jax.Array, cfg: ArchConfig,
                     cache: Dict, pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """x: (B, 1, D); pos: (B,) current absolute position; ring-buffered SWA."""
    b = x.shape[0]
    q, k, v = _qkv(params, x, cfg, positions=pos[:, None])
    cache_len = cache["k"].shape[2]
    slot = pos % cache_len if cfg.window else pos              # (B,)
    k_new = jax.vmap(
        lambda c, kn, s: jax.lax.dynamic_update_slice(c, kn, (0, s, 0))
    )(cache["k"], k, slot)
    v_new = jax.vmap(
        lambda c, vn, s: jax.lax.dynamic_update_slice(c, vn, (0, s, 0))
    )(cache["v"], v, slot)
    k_new = shard(k_new, "dp", "tp_kv", "sp_kv", None)
    v_new = shard(v_new, "dp", "tp_kv", "sp_kv", None)
    if cfg.window:
        # ring buffer holds the last `cache_len` tokens; attend to all valid
        length = jnp.minimum(pos + 1, cache_len)
        y = _ring_decode_attention(q[:, :, 0], k_new, v_new, pos, cache_len, cfg)
    else:
        length = pos + 1
        y = ops.decode_attention(q[:, :, 0], k_new, v_new, length=length)
    y = y.reshape(b, 1, cfg.n_heads * cfg.resolved_head_dim)
    out = jnp.einsum("btk,kd->btd", y, params["wo"].astype(y.dtype))
    return out, {"k": k_new, "v": v_new}


def _ring_decode_attention(q, k, v, pos, cache_len, cfg):
    """Decode over a ring-buffered window cache.

    Every slot is valid once pos+1 >= cache_len; before that only slots
    < pos+1.  Positions inside the window need no causal order for softmax
    (decode attends to the whole window), so a validity mask suffices.
    """
    n_valid = jnp.minimum(pos + 1, cache_len)                 # (B,)
    return _masked_decode(q, k, v, n_valid, cache_len)


def _masked_decode(q, k, v, n_valid, cache_len):
    """jnp decode attention with per-slot validity (ring semantics)."""
    b, h, d = q.shape
    g = k.shape[1]
    if g != h:
        k = jnp.repeat(k, h // g, axis=1)
        v = jnp.repeat(v, h // g, axis=1)
    scale = 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhd,bhkd->bhk", q * scale, k)
    slots = jnp.arange(cache_len)[None, :]
    valid = slots < n_valid[:, None]
    logits = jnp.where(valid[:, None, :], logits.astype(jnp.float32), -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention, MiniCPM3 / DeepSeek-V2)
#
# Queries:  q = W_uq norm(W_dq x)   per head split into (d_nope | d_rope)
# KV:       c = norm(W_dkv x)  (kv_rank)  +  k_rope = W_kr x (d_rope, shared)
#           k_nope = W_uk c ; v = W_uv c  per head
# The decode cache stores ONLY (c, k_rope): rank+d_rope floats per token.


def mla_init(rng, cfg: ArchConfig) -> Dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dt = pdtype(cfg)
    ks = jax.random.split(rng, 8)
    qd = m.d_nope + m.d_rope
    return {
        "w_dq": dense_init(ks[0], d, m.q_rank, dt),
        "q_norm": jnp.ones((m.q_rank,), dt),
        "w_uq": dense_init(ks[1], m.q_rank, h * qd, dt),
        "w_dkv": dense_init(ks[2], d, m.kv_rank, dt),
        "kv_norm": jnp.ones((m.kv_rank,), dt),
        "w_kr": dense_init(ks[3], d, m.d_rope, dt),
        "w_uk": dense_init(ks[4], m.kv_rank, h * m.d_nope, dt),
        "w_uv": dense_init(ks[5], m.kv_rank, h * m.d_v, dt),
        "wo": dense_init(ks[6], h * m.d_v, d, dt),
    }


def _mla_qckr(params, x, cfg, positions):
    m = cfg.mla
    dt = cdtype(cfg)
    b, t, _ = x.shape
    h = cfg.n_heads
    x = x.astype(dt)
    cq = rms_norm(jnp.einsum("btd,dr->btr", x, params["w_dq"].astype(dt)),
                  params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("btr,rk->btk", cq, params["w_uq"].astype(dt)).reshape(
        b, t, h, m.d_nope + m.d_rope)
    q_nope, q_rope = q[..., : m.d_nope], q[..., m.d_nope:]
    q_rope = apply_rope(q_rope.swapaxes(1, 2), positions[:, None],
                        cfg.rope_theta).swapaxes(1, 2)
    c = rms_norm(jnp.einsum("btd,dr->btr", x, params["w_dkv"].astype(dt)),
                 params["kv_norm"], cfg.norm_eps)                 # (B,T,rank)
    k_rope = jnp.einsum("btd,dr->btr", x, params["w_kr"].astype(dt))
    k_rope = apply_rope(k_rope[:, None], positions[:, None],
                        cfg.rope_theta)[:, 0]                     # (B,T,d_rope)
    return q_nope, q_rope, c, k_rope


def _mla_attend(params, q_nope, q_rope, c, k_rope, cfg, causal_offset=0):
    """Full-form MLA attention (materializes per-head k/v from latents)."""
    m = cfg.mla
    h = cfg.n_heads
    dt = q_nope.dtype
    b, tq = q_nope.shape[:2]
    tk = c.shape[1]
    k_nope = jnp.einsum("btr,rk->btk", c, params["w_uk"].astype(dt)).reshape(
        b, tk, h, m.d_nope)
    v = jnp.einsum("btr,rk->btk", c, params["w_uv"].astype(dt)).reshape(
        b, tk, h, m.d_v)
    q = jnp.concatenate([q_nope, q_rope], axis=-1).swapaxes(1, 2)  # (B,H,Tq,·)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, tk, h, m.d_rope))],
        axis=-1,
    ).swapaxes(1, 2)
    v = v.swapaxes(1, 2)
    q = shard(q, "dp", "tp", "sp_attn", None)
    k = shard(k, "dp", "tp", None, None)
    y = ops.flash_attention(q, k, v, causal=True,
                            scale=1.0 / np.sqrt(m.d_nope + m.d_rope),
                            impl=cfg.attn_impl)
    y = y.swapaxes(1, 2).reshape(b, tq, h * m.d_v)
    return jnp.einsum("btk,kd->btd", y, params["wo"].astype(dt))


def mla_apply(params: Dict, x: jax.Array, cfg: ArchConfig,
              positions: jax.Array) -> jax.Array:
    q_nope, q_rope, c, k_rope = _mla_qckr(params, x, cfg, positions)
    out = _mla_attend(params, q_nope, q_rope, c, k_rope, cfg)
    return shard(out, "dp", "sp", None)


def mla_init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Dict:
    m = cfg.mla
    return {
        "c": jnp.zeros((batch, max_len, m.kv_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.d_rope), dtype),
    }


def mla_apply_decode(params: Dict, x: jax.Array, cfg: ArchConfig,
                     cache: Dict, pos: jax.Array) -> Tuple[jax.Array, Dict]:
    b = x.shape[0]
    q_nope, q_rope, c_new, kr_new = _mla_qckr(params, x, cfg, pos[:, None])
    c = jax.vmap(
        lambda cc, cn, s: jax.lax.dynamic_update_slice(cc, cn, (s, 0))
    )(cache["c"], c_new, pos)
    kr = jax.vmap(
        lambda cc, cn, s: jax.lax.dynamic_update_slice(cc, cn, (s, 0))
    )(cache["k_rope"], kr_new, pos)
    m = cfg.mla
    h = cfg.n_heads
    dt = q_nope.dtype
    tk = c.shape[1]
    # latent-space attention: fold W_uk into q (the MLA decode trick) so the
    # cache is read once in compressed form.
    w_uk = params["w_uk"].astype(dt).reshape(m.kv_rank, h, m.d_nope)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)      # (B,H,rank)
    logits = (
        jnp.einsum("bhr,btr->bht", q_lat, c)
        + jnp.einsum("bhe,bte->bht", q_rope[:, 0], kr)
    )
    logits = logits * (1.0 / np.sqrt(m.d_nope + m.d_rope))
    valid = jnp.arange(tk)[None, :] < (pos + 1)[:, None]
    logits = jnp.where(valid[:, None, :], logits.astype(jnp.float32), -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(dt)
    ctx = jnp.einsum("bht,btr->bhr", p, c)                       # (B,H,rank)
    w_uv = params["w_uv"].astype(dt).reshape(m.kv_rank, h, m.d_v)
    y = jnp.einsum("bhr,rhv->bhv", ctx, w_uv).reshape(b, 1, h * m.d_v)
    out = jnp.einsum("btk,kd->btd", y, params["wo"].astype(dt))
    return out, {"c": c, "k_rope": kr}
