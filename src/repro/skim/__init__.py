"""Dataset skimming application (paper §6.2, AGC-style)."""

from .engine import (
    EVENT_SCHEMA, Cuts, make_agc_dataset, skim_file, skim_partitions,
    STRATEGIES,
)

__all__ = ["EVENT_SCHEMA", "Cuts", "make_agc_dataset", "skim_file",
           "skim_partitions", "STRATEGIES"]
