"""AGC-style dataset skimming (paper §6.2), all five strategies of Fig. 5.

Event model (a faithful miniature of the CMS ttbar skim):
    { event_id, met, electrons_pt[], muons_pt[], jets_pt[] }

Three skims, applied together exactly like the paper:
  * horizontal — drop unused columns (schema projection)
  * vertical   — keep events with >=1 electron AND >=1 muon AND >=4 jets
                 above the coarse cut
  * nested     — drop collection elements below the cut

Strategies (paper Fig. 5):
  imt            one sequential writer per partition, page-compression pool
  separate       one file per input shard, then hadd-style merge
  buffermerger   per-worker in-memory files merged from worker threads
  parallel       the paper's parallel writer (one file per partition)
  separate-null  separate files into /dev/null (scalability ceiling)
"""

from __future__ import annotations

import os
import threading
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, replace
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import (
    BufferMerger, Collection, ColumnBatch, F, Leaf, ParallelWriter,
    ReadOptions, RNTJReader, Schema, SequentialWriter, WriteOptions,
    close_all, merge_files,
)
from repro.core.filter import Expr

EVENT_SCHEMA = Schema([
    Leaf("event_id", "int64"),
    Leaf("met", "float32"),
    Collection("electrons_pt", Leaf("_0", "float32")),
    Collection("muons_pt", Leaf("_0", "float32")),
    Collection("jets_pt", Leaf("_0", "float32")),
])

# horizontal skim keeps these fields (drops met)
KEEP_FIELDS = ["event_id", "electrons_pt", "muons_pt", "jets_pt"]

STRATEGIES = ("imt", "separate", "buffermerger", "parallel", "separate-null")


@dataclass(frozen=True)
class Cuts:
    pt_cut: float = 20.0
    min_electrons: int = 1
    min_muons: int = 1
    min_jets: int = 4


def cuts_expr(cuts: Cuts) -> Optional[Expr]:
    """The zone-map pushdown predicate IMPLIED by the vertical skim.

    Conservative by construction: an event passing the cuts necessarily
    has at least one above-``pt_cut`` element in every collection whose
    ``min_*`` is >= 1 (the count thresholds themselves cannot be
    expressed over zone bounds), so pruning by this expression never
    drops an event the kernel would keep — the kernel re-applies the
    exact cuts on whatever survives.  A collection with ``min_* == 0``
    imposes no existential requirement and contributes no atom (an
    electron-only channel must not prune on muons); with every min at
    zero there is nothing to push down and this returns ``None``."""
    atoms = [F(path) > float(cuts.pt_cut)
             for path, need in (("electrons_pt._0", cuts.min_electrons),
                                ("muons_pt._0", cuts.min_muons),
                                ("jets_pt._0", cuts.min_jets))
             if need >= 1]
    if not atoms:
        return None
    expr = atoms[0]
    for a in atoms[1:]:
        expr = expr & a
    return expr


# ---------------------------------------------------------------------------
# synthetic AGC-like dataset


def make_agc_dataset(
    directory: str,
    n_partitions: int = 9,
    files_per_partition: int = 4,
    events_per_file: int = 20_000,
    seed: int = 0,
    options: Optional[WriteOptions] = None,
) -> Dict[int, List[str]]:
    """-> {partition: [input files]} (the paper's 787-file / 9-partition
    layout, scaled to this container)."""
    options = options or WriteOptions(codec="zlib", level=1,
                                      cluster_bytes=2 * 1024 * 1024)
    out: Dict[int, List[str]] = {}
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    for part in range(n_partitions):
        out[part] = []
        for f in range(files_per_partition):
            rng = np.random.default_rng(seed + 1000 * part + f)
            path = str(d / f"part{part}_file{f}.rntj")
            batch = _synth_events(rng, events_per_file,
                                  id0=(part * files_per_partition + f) * events_per_file)
            with SequentialWriter(EVENT_SCHEMA, path, options) as w:
                w.fill_batch(batch)
            out[part].append(path)
    return out


def _synth_events(rng: np.random.Generator, n: int, id0: int) -> ColumnBatch:
    ne = rng.poisson(1.2, n).astype(np.int64)
    nm = rng.poisson(1.2, n).astype(np.int64)
    nj = rng.poisson(6.0, n).astype(np.int64)
    pt = lambda total: rng.exponential(18.0, int(total)).astype(np.float32) + 5.0
    return ColumnBatch.from_arrays(EVENT_SCHEMA, n, {
        "event_id": np.arange(id0, id0 + n, dtype=np.int64),
        "met": rng.exponential(30.0, n).astype(np.float32),
        "electrons_pt": ne, "electrons_pt._0": pt(ne.sum()),
        "muons_pt": nm, "muons_pt._0": pt(nm.sum()),
        "jets_pt": nj, "jets_pt._0": pt(nj.sum()),
    })


# ---------------------------------------------------------------------------
# the skim kernel (vectorized, per cluster)

OUT_SCHEMA = EVENT_SCHEMA.project(KEEP_FIELDS)

# every strategy streams its inputs through the read engine's prefetch
# pipeline: cluster i+1 is read+decoded while the skim kernel chews on i
DEFAULT_READ_OPTIONS = ReadOptions(prefetch_clusters=1)


def _skim_cluster_arrays(
    s: Schema, cols: Dict[int, np.ndarray], n: int, cuts: Cuts
) -> Optional[ColumnBatch]:
    """The vectorized skim kernel over one cluster's column arrays."""

    def coll(path):
        offs = cols[s.column_of_path[path]].astype(np.int64)
        vals = cols[s.column_of_path[path + "._0"]]
        sizes = np.empty_like(offs)
        if len(offs):
            sizes[0] = offs[0]
            np.subtract(offs[1:], offs[:-1], out=sizes[1:])
        return sizes, vals

    e_sz, e_pt = coll("electrons_pt")
    m_sz, m_pt = coll("muons_pt")
    j_sz, j_pt = coll("jets_pt")

    def count_above(sizes, vals):
        mask = vals > cuts.pt_cut
        idx = np.repeat(np.arange(n), sizes)
        return np.bincount(idx, weights=mask.astype(np.float64), minlength=n), mask

    e_cnt, e_keep = count_above(e_sz, e_pt)
    m_cnt, m_keep = count_above(m_sz, m_pt)
    j_cnt, j_keep = count_above(j_sz, j_pt)

    keep = ((e_cnt >= cuts.min_electrons) & (m_cnt >= cuts.min_muons)
            & (j_cnt >= cuts.min_jets))          # vertical skim
    if not keep.any():
        return None

    def nested(sizes, vals, elem_keep):
        ev_of_elem = np.repeat(keep, sizes)
        m = elem_keep & ev_of_elem                 # nested skim
        new_vals = vals[m]
        idx = np.repeat(np.arange(n), sizes)
        new_sizes = np.bincount(idx, weights=m.astype(np.float64), minlength=n)
        return new_sizes[keep].astype(np.int64), new_vals

    e_s, e_v = nested(e_sz, e_pt, e_keep)
    m_s, m_v = nested(m_sz, m_pt, m_keep)
    j_s, j_v = nested(j_sz, j_pt, j_keep)
    ids = cols[s.column_of_path["event_id"]][keep]

    return ColumnBatch.from_arrays(OUT_SCHEMA, int(keep.sum()), {
        "event_id": ids,
        "electrons_pt": e_s, "electrons_pt._0": e_v,
        "muons_pt": m_s, "muons_pt._0": m_v,
        "jets_pt": j_s, "jets_pt._0": j_v,
    })


def _concat_batches(schema: Schema, batches: List[ColumnBatch]) -> ColumnBatch:
    """Concatenate kept sub-batches of ONE input cluster into the single
    batch the unpruned path would have filled (offset columns carry
    per-collection sizes, so concatenation is plain per column)."""
    if len(batches) == 1:
        return batches[0]
    data = {
        c.index: np.concatenate([b.data[c.index] for b in batches])
        for c in schema.columns
    }
    return ColumnBatch(schema, sum(b.n_entries for b in batches), data)


def skim_file(
    in_path: str,
    fill,
    cuts: Cuts,
    read_options: Optional[ReadOptions] = None,
    pushdown: bool = True,
) -> int:
    """Skim one input file into ``fill(batch)``; returns kept events.

    Streams through the read engine's shared entry-range-selection
    helper (``iter_cluster_segments``), so the pruned and unpruned paths
    share partition boundaries: exactly ONE output batch is filled per
    surviving input cluster in both modes, which keeps output files
    byte-identical (DESIGN.md §11).  With ``pushdown`` (default) and no
    explicit ``ReadOptions.filter``, the predicate implied by ``cuts``
    is pushed down; zone-map pruning then skips clusters/pages that
    cannot contain a passing event before any pread.  Cuts that imply
    no predicate (every ``min_*`` at zero), files without zone maps,
    and ``prune=False`` all degrade to the full scan.
    """
    ropts = read_options or DEFAULT_READ_OPTIONS
    if pushdown and ropts.filter is None:
        expr = cuts_expr(cuts)
        if expr is not None:
            ropts = replace(ropts, filter=expr)
    r = RNTJReader(in_path, options=ropts)
    kept = 0
    try:
        for _ci, segments in r.iter_cluster_segments():
            parts = []
            for _e0, cols, n in segments:
                b = _skim_cluster_arrays(r.schema, cols, n, cuts)
                if b is not None:
                    parts.append(b)
            if parts:
                batch = _concat_batches(OUT_SCHEMA, parts)
                fill(batch)
                kept += batch.n_entries
    finally:
        r.close()
    return kept


# ---------------------------------------------------------------------------
# strategies (paper Fig. 5)


def skim_partitions(
    partitions: Dict[int, List[str]],
    out_dir: str,
    strategy: str,
    n_threads: int,
    cuts: Cuts = Cuts(),
    options: Optional[WriteOptions] = None,
    imt_workers: Optional[int] = None,
    read_options: Optional[ReadOptions] = None,
    pushdown: bool = True,
) -> Dict:
    """Skim all partitions with the given strategy; returns stats.

    ``pushdown`` (default on) pushes the predicate implied by ``cuts``
    into every strategy's readers (see :func:`skim_file`): zone-mapped
    inputs prune, legacy inputs full-scan, outputs stay byte-identical.

    Every resource (the thread pool, per-worker writers, merger files) is
    released on the error path too: a worker raising propagates the
    exception instead of leaking threads and half-written files.

    The output writers inherit the I/O engine (DESIGN.md §6) straight
    from ``WriteOptions``: the default enables bounded write-behind, so a
    skim worker seals its next cluster while the previous extent drains
    instead of stalling inside the commit on output-device latency.
    """
    assert strategy in STRATEGIES, strategy
    options = options or WriteOptions(codec="zlib", level=1,
                                      cluster_bytes=2 * 1024 * 1024,
                                      io_inflight_bytes=16 * 1024 * 1024)
    ropts = read_options or DEFAULT_READ_OPTIONS
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    kept_total = [0]
    kept_lock = threading.Lock()

    def add_kept(k):
        with kept_lock:
            kept_total[0] += k

    pool = ThreadPoolExecutor(max_workers=n_threads)
    try:
        if strategy == "imt":
            # parallelize over partitions only; page compression pool inside.
            per_part = max(1, n_threads // max(len(partitions), 1))
            opts = WriteOptions(**{**options.__dict__,
                                   "imt_workers": imt_workers or per_part})
            def run_part(part, files):
                w = SequentialWriter(OUT_SCHEMA, str(out / f"skim_{part}.rntj"),
                                     opts)
                try:
                    for f in files:
                        add_kept(skim_file(f, w.fill_batch, cuts, ropts, pushdown))
                finally:
                    w.close()
            futs = [pool.submit(run_part, p, fs) for p, fs in partitions.items()]
            for fu in futs:
                fu.result()

        elif strategy in ("separate", "separate-null"):
            tmp_files: Dict[int, List[str]] = {p: [] for p in partitions}
            def run_file(part, i, f):
                dst = ("/dev/null" if strategy == "separate-null"
                       else str(out / f"tmp_{part}_{i}.rntj"))
                w = SequentialWriter(OUT_SCHEMA, dst, options)
                try:
                    add_kept(skim_file(f, w.fill_batch, cuts, ropts, pushdown))
                finally:
                    w.close()
                if strategy == "separate":
                    tmp_files[part].append(dst)
            futs = [pool.submit(run_file, p, i, f)
                    for p, fs in partitions.items() for i, f in enumerate(fs)]
            for fu in futs:
                fu.result()
            if strategy == "separate":
                # hadd-style merge per partition (parallel over partitions)
                futs = [pool.submit(merge_files, tmp_files[p],
                                    str(out / f"skim_{p}.rntj"), options)
                        for p in partitions]
                for fu in futs:
                    fu.result()

        elif strategy == "buffermerger":
            mergers = {p: BufferMerger(OUT_SCHEMA, str(out / f"skim_{p}.rntj"),
                                       options) for p in partitions}
            try:
                def run_file(part, f):
                    bmf = mergers[part].get_file()
                    try:
                        add_kept(skim_file(f, bmf.fill_batch, cuts, ropts, pushdown))
                    finally:
                        bmf.close()
                futs = [pool.submit(run_file, p, f)
                        for p, fs in partitions.items() for f in fs]
                for fu in futs:
                    fu.result()
            finally:
                close_all(mergers.values())

        else:  # parallel — the paper's contribution
            writers = {p: ParallelWriter(OUT_SCHEMA, str(out / f"skim_{p}.rntj"),
                                         options) for p in partitions}
            try:
                def run_file(part, f):
                    ctx = writers[part].create_fill_context()
                    try:
                        add_kept(skim_file(f, ctx.fill_batch, cuts, ropts, pushdown))
                    finally:
                        ctx.close()
                futs = [pool.submit(run_file, p, f)
                        for p, fs in partitions.items() for f in fs]
                for fu in futs:
                    fu.result()
            finally:
                close_all(writers.values())
    finally:
        pool.shutdown(wait=True, cancel_futures=True)
    return {"kept_events": kept_total[0], "strategy": strategy,
            "n_threads": n_threads}
