"""Step builders: sharded train / prefill / serve steps for any arch x cell.

Produces jitted functions with explicit in/out shardings for a given mesh:
  * params + optimizer state: FSDP auto-sharding (largest dim over
    pod x data, second over model) — ZeRO-3 style
  * activations: logical-axis constraints inside the model code
  * KV/state caches: generic [stack, dp, tp_kv, sp_kv, ...] pattern whose
    divisibility fallback picks head- or sequence-sharding per arch
  * donation: params/opt_state (train), cache (serve) — in-place buffers

These are exactly the functions the multi-pod dry-run lowers and compiles.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeCell
from repro.distributed.sharding import (
    AxisRules, auto_param_sharding, axis_rules, shard,
)
from repro.models.registry import ModelBundle

from .grad_compress import compress_grads, init_error_state
from .optimizer import AdamW, AdamWState, make_optimizer


def _ns(mesh, *parts):
    return NamedSharding(mesh, P(*parts))


def batch_sharding(mesh: Mesh, shapes: Dict, rules: AxisRules):
    """tokens/labels (B, S[, Q]) -> batch sharded over dp."""
    def one(leaf):
        spec = rules.spec(["dp"] + [None] * (len(leaf.shape) - 1), leaf.shape)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map(one, shapes)


def cache_sharding(mesh: Mesh, cache_shapes, rules: AxisRules):
    """Generic cache rule: [stack, dp, tp_kv, sp_kv, None...].

    The AxisRules divisibility+dedup logic resolves this per tensor: kv
    heads shard over model when they divide it, otherwise the cache
    sequence dim takes the model axis (S-sharded decode), otherwise
    replicate — every assigned arch lowers with this one pattern.
    """
    def one(leaf):
        rank = len(leaf.shape)
        logical = [None, "dp", "tp_kv", "sp_kv"][:rank]
        logical += [None] * (rank - len(logical))
        return NamedSharding(mesh, rules.spec(logical, leaf.shape))
    return jax.tree_util.tree_map(one, cache_shapes)


# ---------------------------------------------------------------------------
# train


def make_train_step(
    bundle: ModelBundle,
    mesh: Mesh,
    optimizer: Optional[AdamW] = None,
    grad_compression: bool = False,
    microbatches: int = 1,
    rules_mapping: Optional[Dict] = None,
    fsdp_axes: Optional[Tuple] = None,
) -> Tuple[Callable, Dict]:
    """-> (jitted step, shardings dict). step(params, opt, batch) -> ..."""
    rules = AxisRules(mesh, rules_mapping)
    opt = optimizer or make_optimizer()

    def loss_fn(params, batch):
        loss, metrics = bundle.loss(params, batch)
        return loss, metrics

    def train_step(params, opt_state, error_state, batch):
        with axis_rules(rules):
            if microbatches > 1:
                grads, loss, metrics = _accumulated_grads(
                    loss_fn, params, batch, microbatches)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            if grad_compression:
                grads, error_state = compress_grads(grads, error_state)
            new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, error_state, {
            "loss": loss.astype(jnp.float32), **{
                k: v.astype(jnp.float32) for k, v in metrics.items()},
        }

    param_shapes = bundle.param_shapes()
    p_sh = auto_param_sharding(param_shapes, mesh, fsdp_axes=fsdp_axes)
    opt_sh = AdamWState(_ns(mesh), p_sh, p_sh)
    err_sh = p_sh if grad_compression else _ns(mesh)
    cell_like = {"tokens": None, "labels": None}

    def in_shardings_for(batch_shapes):
        return (p_sh, opt_sh, err_sh, batch_sharding(mesh, batch_shapes, rules))

    shardings = {
        "params": p_sh,
        "opt": opt_sh,
        "err": err_sh,
        "in_shardings_for": in_shardings_for,
        "rules": rules,
    }

    def jitted(batch_shapes):
        return jax.jit(
            train_step,
            in_shardings=in_shardings_for(batch_shapes),
            out_shardings=(p_sh, opt_sh, err_sh, _ns(mesh)),
            donate_argnums=(0, 1, 2),
        )

    return jitted, shardings


def _accumulated_grads(loss_fn, params, batch, n: int):
    """Gradient accumulation over n microbatches (scan, constant memory)."""
    def split(x):
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])

    micro = jax.tree_util.tree_map(split, batch)

    def body(carry, mb):
        acc, loss_sum = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree_util.tree_map(jnp.add, acc, g)
        return (acc, loss_sum + loss), metrics

    zero = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, loss_sum), metrics = jax.lax.scan(body, (zero, jnp.zeros(())), micro)
    grads = jax.tree_util.tree_map(lambda g: g / n, gsum)
    last_metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
    return grads, loss_sum / n, last_metrics


def init_train_state(bundle: ModelBundle, mesh: Mesh, seed: int = 0,
                     optimizer: Optional[AdamW] = None,
                     grad_compression: bool = False):
    """Materialize sharded params + optimizer state on the mesh."""
    opt = optimizer or make_optimizer()
    param_shapes = bundle.param_shapes()
    p_sh = auto_param_sharding(param_shapes, mesh)

    params = jax.jit(
        lambda: bundle.init(jax.random.PRNGKey(seed)), out_shardings=p_sh
    )()
    opt_state = jax.jit(lambda p: opt.init(p),
                        out_shardings=AdamWState(_ns(mesh), p_sh, p_sh))(params)
    err = (jax.jit(init_error_state, out_shardings=p_sh)(params)
           if grad_compression else jnp.zeros(()))
    return params, opt_state, err


# ---------------------------------------------------------------------------
# serving


def make_prefill_step(bundle: ModelBundle, mesh: Mesh, max_len: int,
                      rules_mapping: Optional[Dict] = None,
                      fsdp_axes: Optional[Tuple] = None):
    rules = AxisRules(mesh, rules_mapping)

    def prefill_step(params, tokens):
        with axis_rules(rules):
            return bundle.prefill(params, tokens, max_len=max_len)

    p_sh = auto_param_sharding(bundle.param_shapes(), mesh,
                               fsdp_axes=fsdp_axes)

    def jitted(token_shapes):
        cache_shapes = jax.eval_shape(
            lambda: bundle.init_cache(token_shapes.shape[0], max_len))
        return jax.jit(
            prefill_step,
            in_shardings=(p_sh, batch_sharding(mesh, token_shapes, rules)),
            out_shardings=(_ns(mesh), cache_sharding(mesh, cache_shapes, rules)),
        )

    return jitted, {"params": p_sh, "rules": rules}


def make_serve_step(bundle: ModelBundle, mesh: Mesh, cell: ShapeCell,
                    rules_mapping: Optional[Dict] = None,
                    fsdp_axes: Optional[Tuple] = None):
    """One-token decode step with a seq_len-sized cache (decode cells)."""
    rules = AxisRules(mesh, rules_mapping)

    def serve_step(params, tokens, cache, pos):
        with axis_rules(rules):
            logits, new_cache = bundle.decode_step(params, tokens, cache, pos)
            next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_cache

    p_sh = auto_param_sharding(bundle.param_shapes(), mesh,
                               fsdp_axes=fsdp_axes)
    cache_shapes = jax.eval_shape(
        lambda: bundle.init_cache(cell.global_batch, cell.seq_len))
    c_sh = cache_sharding(mesh, cache_shapes, rules)
    multi_q = bundle.cfg.n_codebooks > 1
    tok_shape = (
        (cell.global_batch, 1, bundle.cfg.n_codebooks) if multi_q
        else (cell.global_batch, 1)
    )
    tok_sh = NamedSharding(
        mesh, rules.spec(["dp"] + [None] * (len(tok_shape) - 1), tok_shape))
    pos_sh = NamedSharding(mesh, rules.spec(["dp"], (cell.global_batch,)))

    jitted = jax.jit(
        serve_step,
        in_shardings=(p_sh, tok_sh, c_sh, pos_sh),
        out_shardings=(tok_sh, _ns(mesh), c_sh),
        donate_argnums=(2,),
    )
    return jitted, {"params": p_sh, "cache": c_sh, "rules": rules}
