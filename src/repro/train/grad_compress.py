"""Gradient compression: int8 quantization with error feedback.

A distributed-optimization trick for scale: gradients are quantized to int8
per-tensor (symmetric, max-abs scaling) before the data-parallel reduction,
and the quantization error is fed back into the next step's gradients so
the scheme stays unbiased over time (error-feedback SGD).

Under pjit the all-reduce is implicit (GSPMD inserts it for replicated-
parameter gradients / reduce-scatter for FSDP); quantizing the gradient
tensor before the psum boundary shrinks the collective payload 4x vs f32.
The compile-time effect is visible in the §Roofline collective term.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params) -> Dict:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def compress_grads(grads, error_state) -> Tuple[Dict, Dict]:
    """-> (decompressed grads as seen post-allreduce, new error state)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = quantize_int8(gf)
        deq = dequantize_int8(q, scale)
        return deq, gf - deq

    flat = jax.tree_util.tree_map(one, grads, error_state)
    out = jax.tree_util.tree_map(lambda t: t[0], flat,
                                 is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree_util.tree_map(lambda t: t[1], flat,
                                 is_leaf=lambda t: isinstance(t, tuple))
    return out, err
