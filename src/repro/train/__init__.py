"""Training substrate: optimizer, sharded steps, loop, grad compression."""

from .optimizer import AdamW, AdamWState, cosine_schedule, make_optimizer
from .step import (
    init_train_state, make_prefill_step, make_serve_step, make_train_step,
)
from .loop import LoopConfig, StepEvent, TrainLoop

__all__ = [
    "AdamW", "AdamWState", "cosine_schedule", "make_optimizer",
    "init_train_state", "make_prefill_step", "make_serve_step",
    "make_train_step", "LoopConfig", "StepEvent", "TrainLoop",
]
