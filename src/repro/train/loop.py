"""Training loop: data -> step -> checkpoint, with restart & stragglers.

Fault-tolerance behaviour:
  * checkpoint every ``ckpt_every`` steps via the parallel single-file
    writer (async by default — the paper's opt-2 pattern: the loop blocks
    only on the snapshot hand-off);
  * checkpoints carry params, optimizer state AND the loader cursor, so a
    restarted run continues on the exact next batch;
  * on construction the loop restores the latest committed checkpoint if
    one exists (crash-restart is the default path, not a special case);
  * straggler mitigation: per-step wall time is tracked against a rolling
    median; a step slower than ``straggler_factor``x the median fires the
    ``on_straggler`` hook (at fleet scale: re-shard that host's data and
    deprioritize it; here the hook records the event and the test asserts
    the detection fires).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.models.registry import ModelBundle
from repro.pipeline import PackedLoader

from .optimizer import AdamW, make_optimizer
from .step import init_train_state, make_train_step


@dataclass
class LoopConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_async: bool = True
    log_every: int = 10
    keep_ckpts: int = 3
    straggler_factor: float = 3.0
    grad_compression: bool = False
    microbatches: int = 1


@dataclass
class StepEvent:
    step: int
    loss: float
    wall_s: float
    straggler: bool = False


class TrainLoop:
    def __init__(
        self,
        bundle: ModelBundle,
        mesh,
        loader: PackedLoader,
        ckpt_dir: str,
        config: Optional[LoopConfig] = None,
        optimizer: Optional[AdamW] = None,
        on_straggler: Optional[Callable[[StepEvent], None]] = None,
    ):
        self.bundle = bundle
        self.mesh = mesh
        self.loader = loader
        self.config = config or LoopConfig()
        self.optimizer = optimizer or make_optimizer()
        self.mgr = CheckpointManager(ckpt_dir, keep=self.config.keep_ckpts)
        self.on_straggler = on_straggler
        self.history: List[StepEvent] = []
        self._step_times: List[float] = []

        jitted_for, shardings = make_train_step(
            bundle, mesh, optimizer=self.optimizer,
            grad_compression=self.config.grad_compression,
            microbatches=self.config.microbatches,
        )
        self._jitted_for = jitted_for
        self._step_fn = None
        self.step = 0

        latest = self.mgr.latest_step()
        if latest is not None:
            self._restore(latest)
        else:
            self.params, self.opt_state, self.err_state = init_train_state(
                bundle, mesh, optimizer=self.optimizer,
                grad_compression=self.config.grad_compression,
            )

    # -- checkpoint integration ------------------------------------------------

    def _state_tree(self) -> Dict:
        ld = self.loader.state()  # device engine syncs its leftover here
        return {
            "params": self.params,
            "opt": {"step": self.opt_state.step, "m": self.opt_state.m,
                    "v": self.opt_state.v},
            "err": self.err_state,
            "loader": {
                "entry_cursor": np.asarray(ld["entry_cursor"]),
                "leftover": np.asarray(ld["leftover"], np.int32),
            },
        }

    def _save(self) -> None:
        tree = self._state_tree()
        meta = {"train_step": self.step}
        if self.config.ckpt_async:
            self.mgr.save_async(self.step, tree, meta)
        else:
            self.mgr.save(self.step, tree, meta)

    def _restore(self, step: int) -> None:
        from .optimizer import AdamWState

        target = None  # names-based reconstruction
        tree, meta = self.mgr.restore(step)
        self.params = tree["params"]
        o = tree["opt"]
        self.opt_state = AdamWState(o["step"], o["m"], o["v"])
        self.err_state = tree["err"]
        self.loader.load_state({
            "entry_cursor": int(np.asarray(tree["loader"]["entry_cursor"])),
            "leftover": np.asarray(tree["loader"]["leftover"], np.int32),
        })
        self.step = int(meta["train_step"])

    # -- run ----------------------------------------------------------------

    def run(self, steps: Optional[int] = None) -> List[StepEvent]:
        steps = steps if steps is not None else self.config.steps
        batches = self.loader.batches()
        target = self.step + steps
        while self.step < target:
            batch = next(batches)
            jb = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            if self._step_fn is None:
                shapes = jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), jb)
                self._step_fn = self._jitted_for(shapes)
            t0 = time.perf_counter()
            self.params, self.opt_state, self.err_state, metrics = self._step_fn(
                self.params, self.opt_state, self.err_state, jb)
            loss = float(metrics["loss"])
            wall = time.perf_counter() - t0
            self.step += 1

            straggler = False
            if len(self._step_times) >= 5:
                med = float(np.median(self._step_times[-20:]))
                straggler = wall > self.config.straggler_factor * med
            self._step_times.append(wall)
            ev = StepEvent(self.step, loss, wall, straggler)
            self.history.append(ev)
            if straggler and self.on_straggler:
                self.on_straggler(ev)
            if self.step % self.config.log_every == 0:
                print(f"step {self.step:6d}  loss {loss:8.4f}  {wall*1e3:8.1f} ms",
                      flush=True)
            if self.step % self.config.ckpt_every == 0:
                self._save()
        self.mgr.wait()
        return self.history
