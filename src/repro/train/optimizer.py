"""AdamW optimizer + LR schedules (pure pytree implementation).

Optimizer states share the parameter sharding (ZeRO: m/v are FSDP-sharded
exactly like their parameters), so the dry-run memory analysis reflects a
real sharded-optimizer training step.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class AdamWState(NamedTuple):
    step: jax.Array          # ()
    m: Dict                  # like params
    v: Dict                  # like params


@dataclass(frozen=True)
class AdamW:
    schedule: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p
        )
        return AdamWState(jnp.zeros((), jnp.int32), zeros(params), zeros(params))

    def update(self, grads, state: AdamWState, params) -> Tuple[Dict, AdamWState]:
        step = state.step + 1
        gf = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gnorm = global_norm(gf)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            gf = jax.tree_util.tree_map(lambda g: g * scale, gf)
        m = jax.tree_util.tree_map(
            lambda mm, g: self.b1 * mm + (1 - self.b1) * g, state.m, gf)
        v = jax.tree_util.tree_map(
            lambda vv, g: self.b2 * vv + (1 - self.b2) * g * g, state.v, gf)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.schedule(step)

        def upd(p, mm, vv):
            mhat = mm / bc1
            vhat = vv / bc2
            u = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, AdamWState(step, m, v)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(tree))
    )


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5 *
                         (1 + jnp.cos(np.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return f


def make_optimizer(peak_lr: float = 3e-4, warmup: int = 100,
                   total: int = 10_000, **kw) -> AdamW:
    return AdamW(schedule=cosine_schedule(peak_lr, warmup, total), **kw)
