"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels are property-tested against
(``interpret=True`` on CPU), and they double as the portable fallback the
models use when not running on TPU.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Columnar encoders (the paper's serialization hot spots)


def offsets_scan_ref(lengths: jax.Array) -> jax.Array:
    """Collection sizes -> cluster-relative end offsets (inclusive scan)."""
    return jnp.cumsum(lengths, axis=-1)


def byteshuffle_ref(planes: jax.Array) -> jax.Array:
    """Split encoding: (N, itemsize) uint8 byte planes -> (itemsize, N)."""
    return planes.T


def delta_zigzag_ref(x: jax.Array) -> jax.Array:
    """delta (vs previous element, first absolute) then zigzag, elementwise."""
    d = jnp.concatenate([x[:1], x[1:] - x[:-1]])
    bits = jnp.dtype(x.dtype).itemsize * 8 - 1
    return ((d << 1) ^ (d >> bits)).astype(
        jnp.uint32 if x.dtype == jnp.int32 else jnp.uint64
    )


# -- the decode chain (read-side inverses, DESIGN.md §9) --------------------


def unsplit_pages_ref(planes: jax.Array) -> jax.Array:
    """Inverse page-batched byteshuffle: (P, itemsize, per) -> (P, per, itemsize)."""
    return jnp.swapaxes(planes, 1, 2)


def unzigzag_ref(z: jax.Array) -> jax.Array:
    """zigzag inverse on uint32 lanes -> int32: (z >> 1) ^ -(z & 1)."""
    z = z.astype(jnp.uint32)
    return (z >> 1).astype(jnp.int32) ^ -(z & 1).astype(jnp.int32)


def decode_offset_pages_ref(planes: jax.Array) -> jax.Array:
    """Fused offset-column decode oracle, (P, 8, per) uint8 -> (P, per) int32.

    Byte planes of the stored uint64 zigzag deltas (low 32 bits only —
    the dispatcher guards that offsets fit) -> zigzag inverse -> per-page
    inclusive scan (per-page delta restart: each page integrates from 0).
    """
    p = planes.astype(jnp.uint32)
    z = p[:, 0] | (p[:, 1] << 8) | (p[:, 2] << 16) | (p[:, 3] << 24)
    return jnp.cumsum(unzigzag_ref(z), axis=-1)


# ---------------------------------------------------------------------------
# Attention


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, G, S, D) -> (B, H, S, D) by repeating each kv head H//G times."""
    b, g, s, d = k.shape
    if g == n_heads:
        return k
    return jnp.repeat(k, n_heads // g, axis=1)


def flash_attention_ref(
    q: jax.Array,            # (B, H, Sq, D)
    k: jax.Array,            # (B, G, Sk, D)
    v: jax.Array,            # (B, G, Sk, D)
    causal: bool = True,
    window: Optional[int] = None,     # sliding-window attention size
    scale: Optional[float] = None,
) -> jax.Array:
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    kk = _expand_kv(k, h)
    vv = _expand_kv(v, h)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q * scale, kk)
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)   # align ends (prefill/decode)
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv)


def flash_attention_chunked(
    q: jax.Array,            # (B, H, Sq, D)
    k: jax.Array,            # (B, G, Sk, D)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block: int = 1024,
) -> jax.Array:
    """Pure-JAX online-softmax attention (scan over kv blocks).

    The §Perf optimization for the memory roofline term: never materializes
    the (Sq, Sk) score matrix — per-iteration footprint is (Sq, block).
    Mathematically identical to :func:`flash_attention_ref`; on TPU the
    Pallas kernel replaces it, on CPU/dry-run this IS the compiled form.
    """
    b, h, sq, d = q.shape
    g, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]                     # may differ from d (MLA)
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    block = min(block, sk)
    pad = (-sk) % block
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = kp.shape[2] // block
    kk = _expand_kv(kp, h).reshape(b, h, nk, block, d)
    vv = _expand_kv(vp, h).reshape(b, h, nk, block, dv)
    q32 = (q * scale).astype(jnp.float32)
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, ik = xs                      # (B,H,block,D) x2, ()
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, kb.astype(jnp.float32))
        k_pos = ik * block + jnp.arange(block)[None, :]
        mask = k_pos < sk
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask[None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.moveaxis(kk, 2, 0), jnp.moveaxis(vv, 2, 0), jnp.arange(nk)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,            # (B, H, D) — one new token
    k: jax.Array,            # (B, G, S, D) — KV cache
    v: jax.Array,
    length: Optional[jax.Array] = None,   # (B,) valid cache lengths
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    b, h, d = q.shape
    s = k.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    kk = _expand_kv(k, h)
    vv = _expand_kv(v, h)
    logits = jnp.einsum("bhd,bhkd->bhk", q * scale, kk)
    pos = jnp.arange(s)[None, :]
    valid = jnp.ones((b, s), dtype=bool)
    if length is not None:
        valid &= pos < length[:, None]
        last = length[:, None]
    else:
        last = jnp.full((b, 1), s)
    if window is not None:
        valid &= pos >= last - window
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p.astype(vv.dtype), vv)


# ---------------------------------------------------------------------------
# RWKV-6 (Finch) wkv recurrence
#
#   S_t = diag(w_t) S_{t-1} + k_t^T v_t        S: (Dk, Dv) per (batch, head)
#   o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
#
# w_t in (0,1) is the data-dependent decay; u is the per-channel bonus.


def rwkv6_ref(
    r: jax.Array,    # (B, H, T, Dk)
    k: jax.Array,    # (B, H, T, Dk)
    v: jax.Array,    # (B, H, T, Dv)
    w: jax.Array,    # (B, H, T, Dk) decay in (0, 1)
    u: jax.Array,    # (H, Dk) bonus
    initial_state: Optional[jax.Array] = None,  # (B, H, Dk, Dv)
):
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    r_, k_, v_, w_ = (x.astype(f32) for x in (r, k, v, w))
    u_ = u.astype(f32)
    s0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((b, h, dk, dv), f32)
    )

    def step(S, xs):
        rt, kt, vt, wt = xs          # (B,H,Dk),(B,H,Dk),(B,H,Dv),(B,H,Dk)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,Dk,Dv)
        ot = jnp.einsum(
            "bhk,bhkv->bhv", rt, S + u_[None, :, :, None] * kv
        )
        S = wt[..., :, None] * S + kv
        return S, ot

    xs = tuple(jnp.moveaxis(x, 2, 0) for x in (r_, k_, v_, w_))
    S, out = jax.lax.scan(step, s0, xs)
    out = jnp.moveaxis(out, 0, 2)    # (B, H, T, Dv)
    return out.astype(v.dtype), S


def rwkv6_decode_ref(r, k, v, w, u, state):
    """One-token RWKV6 step: inputs (B,H,Dk)... state (B,H,Dk,Dv)."""
    out, new_state = rwkv6_ref(
        r[:, :, None], k[:, :, None], v[:, :, None], w[:, :, None], u, state
    )
    return out[:, :, 0], new_state


# ---------------------------------------------------------------------------
# Mamba-2 (SSD): scalar-decay per head state-space recurrence
#
#   H_t = exp(a_t) H_{t-1} + B_t^T (dt_t * x_t)    H: (N, P) per (batch, head)
#   y_t = C_t H_t + D x_t
#
# a_t = -softplus-parameterized decay * dt (precomputed by caller as log-decay)


def mamba2_ref(
    x: jax.Array,        # (B, H, T, P) head channels
    log_a: jax.Array,    # (B, H, T) log decay (<= 0)
    Bm: jax.Array,       # (B, T, N) input projection (shared across heads)
    Cm: jax.Array,       # (B, T, N) output projection
    D: jax.Array,        # (H,) skip
    initial_state: Optional[jax.Array] = None,  # (B, H, N, P)
):
    b, h, t, p = x.shape
    n = Bm.shape[-1]
    f32 = jnp.float32
    x_, la, B_, C_ = (a.astype(f32) for a in (x, log_a, Bm, Cm))
    s0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((b, h, n, p), f32)
    )

    def step(H, xs):
        xt, lat, bt, ct = xs         # (B,H,P),(B,H),(B,N),(B,N)
        H = jnp.exp(lat)[..., None, None] * H + jnp.einsum(
            "bn,bhp->bhnp", bt, xt
        )
        yt = jnp.einsum("bn,bhnp->bhp", ct, H)
        return H, yt

    xs = (
        jnp.moveaxis(x_, 2, 0),
        jnp.moveaxis(la, 2, 0),
        jnp.moveaxis(B_, 1, 0),
        jnp.moveaxis(C_, 1, 0),
    )
    Hf, y = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(y, 0, 2) + D[None, :, None, None].astype(f32) * x_
    return y.astype(x.dtype), Hf


def mamba2_decode_ref(x, log_a, Bm, Cm, D, state):
    """One-token Mamba2 step: x (B,H,P), log_a (B,H), Bm/Cm (B,N)."""
    y, new_state = mamba2_ref(
        x[:, :, None], log_a[:, :, None], Bm[:, None], Cm[:, None], D, state
    )
    return y[:, :, 0], new_state
