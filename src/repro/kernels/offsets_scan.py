"""Pallas TPU kernel: collection sizes -> cluster-relative end offsets.

The offset-column construction (inclusive prefix sum) is the central
nested-data transform of the paper's format (§3): every variable-length
collection's sizes are integrated into cluster-relative offsets at seal
time.  On TPU this runs as a single-pass blocked scan: the grid is
sequential on a TensorCore, so the running carry lives in SMEM scratch and
flows across block invocations; each block computes its local cumsum in
VMEM and adds the carry.

This is also exactly the primitive a *distributed* writer needs to turn
per-host cluster sizes into file extents (DESIGN.md §3.2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

import numpy as np

DEFAULT_BLOCK = 4096


def _scan_kernel(x_ref, o_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        carry_ref[0] = jnp.zeros((), x_ref.dtype)

    local = jnp.cumsum(x_ref[...])
    o_ref[...] = local + carry_ref[0]
    carry_ref[0] = carry_ref[0] + local[-1]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def offsets_scan(
    lengths: jax.Array, block: int = DEFAULT_BLOCK, interpret: bool = False
) -> jax.Array:
    """Inclusive scan over a 1-D array of collection sizes."""
    (n,) = lengths.shape
    pad = (-n) % block
    x = jnp.pad(lengths, (0, pad))
    out = pl.pallas_call(
        _scan_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(x.shape[0] // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        scratch_shapes=[pltpu.SMEM((1,), x.dtype)],
        interpret=interpret,
    )(x)
    return out[:n]


def offsets_scan_host(
    sizes: np.ndarray, block: int = DEFAULT_BLOCK
) -> np.ndarray:
    """Numpy-in / numpy-out entry point for the write hot path.

    Accepts a 1-D array of collection sizes and returns int64
    cluster-relative end offsets.  The kernel runs in int32 (the Pallas
    lane width); callers must ensure the total fits — the write path
    guards this and falls back to numpy otherwise.  On a CPU-only jax
    backend the kernel runs in interpret mode (used by tests; the
    dispatcher in ``repro.core.encoding`` does not select this path on
    CPU unless forced).
    """
    x = jnp.asarray(np.ascontiguousarray(sizes), dtype=jnp.int32)
    interpret = jax.default_backend() == "cpu"
    out = offsets_scan(x, block=block, interpret=interpret)
    return np.asarray(out, dtype=np.int64)
