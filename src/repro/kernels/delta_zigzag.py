"""Pallas TPU kernel: delta + zigzag preconditioning of offset columns.

RNTuple's offset columns are stored delta-encoded (paper §3 / our
``encoding.ENC_DELTA_ZIGZAG_SPLIT``): element i becomes
``zigzag(x[i] - x[i-1])`` with the first element absolute.  The previous
block's last element is carried across grid steps in SMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 4096


def _dz_kernel(x_ref, o_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        carry_ref[0] = jnp.zeros((), x_ref.dtype)

    x = x_ref[...]
    prev = jnp.concatenate([carry_ref[0][None], x[:-1]])
    d = x - prev
    bits = x.dtype.itemsize * 8 - 1
    z = (d << 1) ^ (d >> bits)
    o_ref[...] = z.astype(o_ref.dtype)
    carry_ref[0] = x[-1]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def delta_zigzag(
    x: jax.Array, block: int = DEFAULT_BLOCK, interpret: bool = False
) -> jax.Array:
    (n,) = x.shape
    out_dtype = jnp.uint32 if x.dtype == jnp.int32 else jnp.uint64
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad))
    out = pl.pallas_call(
        _dz_kernel,
        out_shape=jax.ShapeDtypeStruct(xp.shape, out_dtype),
        grid=(xp.shape[0] // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        scratch_shapes=[pltpu.SMEM((1,), x.dtype)],
        interpret=interpret,
    )(xp)
    return out[:n]
