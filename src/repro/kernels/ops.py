"""Public kernel entry points with backend dispatch.

On TPU the Pallas kernels run compiled; everywhere else (this CPU
container, tests) they run through ``interpret=True`` or fall back to the
``ref`` oracles.  Model code calls these wrappers only.

``use_pallas``: None = auto (pallas on TPU, ref elsewhere), True = force
pallas (interpret on CPU), False = force ref.

This module also owns :class:`KernelDispatch` — the ONE auto/numpy/pallas
backend selector shared by every host-facing encode/decode kernel
(offsets scan, byteshuffle, the device decode chain).  The module itself
stays import-light: jax and the kernel implementations load lazily inside
the wrappers, so ``from repro.kernels.ops import KernelDispatch`` costs
nothing on the write/read hot paths that only need the dispatch logic.
"""

from __future__ import annotations

import os
import sys
from typing import Callable, Optional

# ---------------------------------------------------------------------------
# host-side backend dispatch (shared by core/encoding.py and the reader's
# device decode path)

#: the global default backend for every dispatched kernel; per-kernel
#: ``REPRO_<NAME>_BACKEND`` variables override it (DESIGN.md §7.4)
GLOBAL_BACKEND_ENV = "REPRO_KERNEL_BACKEND"


class KernelDispatch:
    """auto / numpy / pallas backend selection for one kernel family.

    Consolidates what used to be a per-kernel copy of the same logic in
    ``core/encoding.py`` (ISSUE 7 satellite): environment resolution,
    the "auto never pays a cold jax import on the hot path" rule, the
    size floor below which the host fallback always wins, and the
    rule-out-once-on-failure cache.

    Resolution order for the backend string:

    1. ``REPRO_<NAME>_BACKEND`` — the per-kernel override;
    2. ``REPRO_KERNEL_BACKEND`` — the global default for all kernels;
    3. ``"auto"``.

    ``auto`` selects the Pallas kernel only when jax is *already
    imported* by the application (never pay a multi-second cold import
    inside a seal or decode path) AND the default backend is an
    accelerator; ``pallas`` forces the kernel (interpret mode on CPU —
    the bit-identity test configuration); ``numpy`` pins the host
    fallback.  The size floor ``REPRO_<NAME>_PALLAS_MIN`` (units chosen
    by the call site: elements or bytes) only gates ``auto``.

    The instance is mutable on purpose: tests monkeypatch ``backend``
    and reset ``_kernel`` to re-resolve under an override.
    """

    def __init__(
        self,
        name: str,
        loader: Callable[[], Callable],
        min_default: int,
        device_only: bool = True,
    ) -> None:
        self.name = name
        env = f"REPRO_{name.upper()}_BACKEND"
        self.backend = os.environ.get(
            env, os.environ.get(GLOBAL_BACKEND_ENV, "auto")
        ).lower()
        self.min = int(
            os.environ.get(f"REPRO_{name.upper()}_PALLAS_MIN", str(min_default))
        )
        self._loader = loader
        self._device_only = device_only
        self._kernel: Optional[Callable] = None  # None = unresolved; False = out

    def want(self, measure: int) -> bool:
        """Should this call even consider the kernel? (size gate)"""
        if self.backend == "pallas":
            return True
        return self.backend == "auto" and measure >= self.min

    def resolve(self) -> Optional[Callable]:
        """The kernel callable, or a falsy value when ruled out.

        In ``auto`` mode a missing jax import stays *unresolved* (returns
        ``False`` without caching the negative) so a later jax import can
        still enable the kernel; a CPU-only jax backend rules the kernel
        out for good (interpret mode exists for correctness tests, not
        speed).
        """
        if self._kernel is None:
            if self.backend != "pallas" and "jax" not in sys.modules:
                return False
            try:
                import jax

                kernel = self._loader()
                if (
                    self._device_only
                    and self.backend != "pallas"
                    and jax.default_backend() == "cpu"
                ):
                    self._kernel = False
                else:
                    self._kernel = kernel
            except Exception:
                self._kernel = False
        return self._kernel

    def disable(self) -> None:
        """Rule the kernel out after a runtime failure (fallback stays)."""
        self._kernel = False


def _on_accelerator() -> bool:
    """True when jax is already imported AND its default backend is an
    accelerator — the ``auto`` rule every dispatcher shares."""
    if "jax" not in sys.modules:
        return False
    try:
        import jax

        return jax.default_backend() != "cpu"
    except Exception:  # pragma: no cover
        return False


# ---------------------------------------------------------------------------
# model-kernel entry points (jax imported lazily per call)


def _on_tpu() -> bool:
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve(use_pallas: Optional[bool]):
    """-> (run_pallas, interpret)"""
    if use_pallas is None:
        return (_on_tpu(), False)
    return (use_pallas, not _on_tpu())


def offsets_scan(lengths, use_pallas: Optional[bool] = None, **kw):
    run, interp = _resolve(use_pallas)
    if run:
        from .offsets_scan import offsets_scan as k

        return k(lengths, interpret=interp, **kw)
    from . import ref

    return ref.offsets_scan_ref(lengths)


def delta_zigzag(x, use_pallas: Optional[bool] = None, **kw):
    run, interp = _resolve(use_pallas)
    if run:
        from .delta_zigzag import delta_zigzag as k

        return k(x, interpret=interp, **kw)
    from . import ref

    return ref.delta_zigzag_ref(x)


def byteshuffle(planes, use_pallas: Optional[bool] = None, **kw):
    run, interp = _resolve(use_pallas)
    if run:
        from .byteshuffle import byteshuffle as k

        return k(planes, interpret=interp, **kw)
    from . import ref

    return ref.byteshuffle_ref(planes)


def unsplit_pages(planes, use_pallas: Optional[bool] = None, **kw):
    """Inverse page-batched byteshuffle: (P, itemsize, per) -> (P, per, itemsize)."""
    run, interp = _resolve(use_pallas)
    if run:
        from .decode_pages import unsplit_pages as k

        return k(planes, interpret=interp, **kw)
    from . import ref

    return ref.unsplit_pages_ref(planes)


def decode_offset_pages(planes, use_pallas: Optional[bool] = None, **kw):
    """Fused offset-column decode: split u64 zigzag deltas -> int32 offsets."""
    run, interp = _resolve(use_pallas)
    if run:
        from .decode_pages import decode_offset_pages as k

        return k(planes, interpret=interp, **kw)
    from . import ref

    return ref.decode_offset_pages_ref(planes)


def flash_attention(q, k, v, causal=True, window=None, scale=None,
                    use_pallas: Optional[bool] = None, impl: str = "ref", **kw):
    """impl: "ref" (naive softmax — the paper-faithful baseline shape) or
    "chunked" (online-softmax scan over kv blocks — the §Perf variant)."""
    run, interp = _resolve(use_pallas)
    if run:
        from .flash_attention import flash_attention as kern

        return kern(q, k, v, causal=causal, window=window,
                    scale=scale, interpret=interp, **kw)
    from . import ref

    if impl == "chunked":
        return ref.flash_attention_chunked(q, k, v, causal=causal,
                                           window=window, scale=scale)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   scale=scale)


def decode_attention(q, k, v, length=None, window=None, scale=None,
                     use_pallas: Optional[bool] = None, **kw):
    run, interp = _resolve(use_pallas)
    if run:
        from .decode_attention import decode_attention as kern

        return kern(q, k, v, length=length, window=window,
                    scale=scale, interpret=interp, **kw)
    from . import ref

    return ref.decode_attention_ref(q, k, v, length=length, window=window,
                                    scale=scale)


def rwkv6(r, k, v, w, u, use_pallas: Optional[bool] = None, **kw):
    """-> (out (B,H,T,Dv), final_state (B,H,Dk,Dv))."""
    run, interp = _resolve(use_pallas)
    if run:
        from .rwkv6_scan import rwkv6_scan as kern

        return kern(r, k, v, w, u, interpret=interp, **kw)
    from . import ref

    return ref.rwkv6_ref(r, k, v, w, u)


def mamba2(x, log_a, Bm, Cm, use_pallas: Optional[bool] = None, **kw):
    """-> (out (B,H,T,P) without D-skip, final_state (B,H,N,P))."""
    run, interp = _resolve(use_pallas)
    if run:
        from .mamba2_ssd import mamba2_ssd as kern

        return kern(x, log_a, Bm, Cm, interpret=interp, **kw)
    import jax

    from . import ref

    D0 = jax.numpy.zeros((x.shape[1],), x.dtype)
    return ref.mamba2_ref(x, log_a, Bm, Cm, D0)
