"""Public kernel entry points with backend dispatch.

On TPU the Pallas kernels run compiled; everywhere else (this CPU
container, tests) they run through ``interpret=True`` or fall back to the
``ref`` oracles.  Model code calls these wrappers only.

``use_pallas``: None = auto (pallas on TPU, ref elsewhere), True = force
pallas (interpret on CPU), False = force ref.
"""

from __future__ import annotations

from typing import Optional

import jax

from . import ref
from .byteshuffle import byteshuffle as _byteshuffle
from .decode_attention import decode_attention as _decode_attention
from .delta_zigzag import delta_zigzag as _delta_zigzag
from .flash_attention import flash_attention as _flash_attention
from .mamba2_ssd import mamba2_ssd as _mamba2_ssd
from .offsets_scan import offsets_scan as _offsets_scan
from .rwkv6_scan import rwkv6_scan as _rwkv6_scan


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve(use_pallas: Optional[bool]):
    """-> (run_pallas, interpret)"""
    if use_pallas is None:
        return (_on_tpu(), False)
    return (use_pallas, not _on_tpu())


def offsets_scan(lengths, use_pallas: Optional[bool] = None, **kw):
    run, interp = _resolve(use_pallas)
    if run:
        return _offsets_scan(lengths, interpret=interp, **kw)
    return ref.offsets_scan_ref(lengths)


def delta_zigzag(x, use_pallas: Optional[bool] = None, **kw):
    run, interp = _resolve(use_pallas)
    if run:
        return _delta_zigzag(x, interpret=interp, **kw)
    return ref.delta_zigzag_ref(x)


def byteshuffle(planes, use_pallas: Optional[bool] = None, **kw):
    run, interp = _resolve(use_pallas)
    if run:
        return _byteshuffle(planes, interpret=interp, **kw)
    return ref.byteshuffle_ref(planes)


def flash_attention(q, k, v, causal=True, window=None, scale=None,
                    use_pallas: Optional[bool] = None, impl: str = "ref", **kw):
    """impl: "ref" (naive softmax — the paper-faithful baseline shape) or
    "chunked" (online-softmax scan over kv blocks — the §Perf variant)."""
    run, interp = _resolve(use_pallas)
    if run:
        return _flash_attention(q, k, v, causal=causal, window=window,
                                scale=scale, interpret=interp, **kw)
    if impl == "chunked":
        return ref.flash_attention_chunked(q, k, v, causal=causal,
                                           window=window, scale=scale)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   scale=scale)


def decode_attention(q, k, v, length=None, window=None, scale=None,
                     use_pallas: Optional[bool] = None, **kw):
    run, interp = _resolve(use_pallas)
    if run:
        return _decode_attention(q, k, v, length=length, window=window,
                                 scale=scale, interpret=interp, **kw)
    return ref.decode_attention_ref(q, k, v, length=length, window=window,
                                    scale=scale)


def rwkv6(r, k, v, w, u, use_pallas: Optional[bool] = None, **kw):
    """-> (out (B,H,T,Dv), final_state (B,H,Dk,Dv))."""
    run, interp = _resolve(use_pallas)
    if run:
        return _rwkv6_scan(r, k, v, w, u, interpret=interp, **kw)
    return ref.rwkv6_ref(r, k, v, w, u)


def mamba2(x, log_a, Bm, Cm, use_pallas: Optional[bool] = None, **kw):
    """-> (out (B,H,T,P) without D-skip, final_state (B,H,N,P))."""
    run, interp = _resolve(use_pallas)
    if run:
        return _mamba2_ssd(x, log_a, Bm, Cm, interpret=interp, **kw)
    D0 = jax.numpy.zeros((x.shape[1],), x.dtype)
    return ref.mamba2_ref(x, log_a, Bm, Cm, D0)
