"""Pallas TPU kernel: Mamba-2 SSD chunked scan.

Per (batch, head) recurrence with *scalar* decay (the SSD restriction that
buys the matmul form):

    H_t = e^{a_t} H_{t-1} + B_t^T x_t        H: (N, P)
    y_t = C_t H_t (+ D x_t, applied by the wrapper)

Chunked SSD (Dao & Gu 2024), with ca = inclusive cumsum of log-decay within
the chunk:

    y   = (e^{ca} C) H_0                         (state term, matmul)
        + [(C B^T) . L] x                        (intra-chunk, L[t,s]=e^{ca_t-ca_s}, s<=t)
    H_C = e^{ca_{C-1}} H_0 + (e^{ca_{C-1}-ca} B)^T x

All exponents are <= 0, numerically safe.  Inter-chunk state flows through
VMEM scratch across sequential grid steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _ssd_kernel(x_ref, la_ref, b_ref, c_ref, o_ref, hout_ref, h_ref,
                *, chunk: int, n_chunks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)      # (C, P)
    la = la_ref[0, 0].astype(jnp.float32)    # (C,)
    B = b_ref[0].astype(jnp.float32)         # (C, N)
    Cm = c_ref[0].astype(jnp.float32)        # (C, N)
    H = h_ref[...]                           # (N, P)

    ca = jnp.cumsum(la)                      # (C,)
    # state term
    y_state = jnp.dot(Cm * jnp.exp(ca)[:, None], H,
                      preferred_element_type=jnp.float32)
    # intra-chunk
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(s_idx <= t_idx, jnp.exp(ca[:, None] - ca[None, :]), 0.0)
    G = jnp.dot(Cm, B.T, preferred_element_type=jnp.float32) * L
    y = y_state + jnp.dot(G, x, preferred_element_type=jnp.float32)
    # inter-chunk state update
    decay_out = jnp.exp(ca[-1])
    b_scaled = B * jnp.exp(ca[-1] - ca)[:, None]
    h_ref[...] = decay_out * H + jnp.dot(
        b_scaled.T, x, preferred_element_type=jnp.float32
    )
    o_ref[0, 0] = y.astype(o_ref.dtype)

    @pl.when(it == n_chunks - 1)
    def _():
        hout_ref[0, 0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(
    x: jax.Array,        # (B, H, T, P)
    log_a: jax.Array,    # (B, H, T)
    Bm: jax.Array,       # (B, T, N)
    Cm: jax.Array,       # (B, T, N)
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
):
    b, h, t, p = x.shape
    n = Bm.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nt = t // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nt)
    out, state = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b, h, t, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ),
        grid=(b, h, nt),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, it: (b_, h_, it, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b_, h_, it: (b_, h_, it)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, it: (b_, it, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, it: (b_, it, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, it: (b_, h_, it, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_, it: (b_, h_, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, log_a, Bm, Cm)
    return out, state
