"""Pallas TPU kernel: byte-plane split ("byteshuffle") encoding.

RNTuple's split encoding (our ``encoding.ENC_SPLIT``) stores byte plane j
of every element consecutively, which makes float/int pages dramatically
more compressible (paper §3).  As a layout transform it is bandwidth-bound:
the kernel tiles the (N, itemsize) byte matrix through VMEM and writes the
(itemsize, N) transpose.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def _shuffle_kernel(x_ref, o_ref):
    # x block: (BN, itemsize) uint8 -> out block (itemsize, BN)
    o_ref[...] = x_ref[...].T


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def byteshuffle(
    planes: jax.Array, block: int = DEFAULT_BLOCK, interpret: bool = False
) -> jax.Array:
    """(N, itemsize) uint8 -> (itemsize, N) uint8 (byte planes)."""
    n, itemsize = planes.shape
    pad = (-n) % block
    x = jnp.pad(planes, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _shuffle_kernel,
        out_shape=jax.ShapeDtypeStruct((itemsize, x.shape[0]), jnp.uint8),
        grid=(x.shape[0] // block,),
        in_specs=[pl.BlockSpec((block, itemsize), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((itemsize, block), lambda i: (0, i)),
        interpret=interpret,
    )(x)
    return out[:, :n]


def _shuffle_pages_kernel(x_ref, o_ref):
    # x block: (1, BN, itemsize) uint8 -> out block (1, itemsize, BN)
    o_ref[...] = jnp.swapaxes(x_ref[...], 1, 2)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def byteshuffle_pages(
    pages: jax.Array, block: int = DEFAULT_BLOCK, interpret: bool = False
) -> jax.Array:
    """(P, per, itemsize) uint8 -> (P, itemsize, per): page-wise planes.

    The column-batched form the seal hot path wants: every full page of a
    column is split in one kernel launch, page ``p``'s byte planes landing
    contiguously in ``out[p]``.  The grid walks (page, block-within-page);
    a page is its own independent transpose, so blocks never cross page
    boundaries.
    """
    n_pages, per, itemsize = pages.shape
    blk = min(block, per)
    pad = (-per) % blk
    x = jnp.pad(pages, ((0, 0), (0, pad), (0, 0)))
    out = pl.pallas_call(
        _shuffle_pages_kernel,
        out_shape=jax.ShapeDtypeStruct(
            (n_pages, itemsize, x.shape[1]), jnp.uint8
        ),
        grid=(n_pages, x.shape[1] // blk),
        in_specs=[pl.BlockSpec((1, blk, itemsize), lambda i, j: (i, j, 0))],
        out_specs=pl.BlockSpec((1, itemsize, blk), lambda i, j: (i, 0, j)),
        interpret=interpret,
    )(x)
    return out[:, :, :per]


def byteshuffle_host(planes) -> "jax.Array":
    """Numpy-in / numpy-out single-buffer entry point.

    ``planes`` is the (N, itemsize) uint8 view of one contiguous
    primitive array; returns the (itemsize, N) plane-split matrix as a
    host array.  On a CPU-only jax backend the kernel runs in interpret
    mode (used by tests; the dispatcher in ``repro.core.encoding`` does
    not select this path on CPU unless forced).
    """
    import numpy as np

    x = jnp.asarray(np.ascontiguousarray(planes), dtype=jnp.uint8)
    interpret = jax.default_backend() == "cpu"
    return np.asarray(byteshuffle(x, interpret=interpret))


def byteshuffle_pages_host(pages) -> "jax.Array":
    """Numpy-in / numpy-out page-batched entry point (seal hot path)."""
    import numpy as np

    x = jnp.asarray(np.ascontiguousarray(pages), dtype=jnp.uint8)
    interpret = jax.default_backend() == "cpu"
    return np.asarray(byteshuffle_pages(x, interpret=interpret))
