"""Pallas TPU kernel: byte-plane split ("byteshuffle") encoding.

RNTuple's split encoding (our ``encoding.ENC_SPLIT``) stores byte plane j
of every element consecutively, which makes float/int pages dramatically
more compressible (paper §3).  As a layout transform it is bandwidth-bound:
the kernel tiles the (N, itemsize) byte matrix through VMEM and writes the
(itemsize, N) transpose.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def _shuffle_kernel(x_ref, o_ref):
    # x block: (BN, itemsize) uint8 -> out block (itemsize, BN)
    o_ref[...] = x_ref[...].T


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def byteshuffle(
    planes: jax.Array, block: int = DEFAULT_BLOCK, interpret: bool = False
) -> jax.Array:
    """(N, itemsize) uint8 -> (itemsize, N) uint8 (byte planes)."""
    n, itemsize = planes.shape
    pad = (-n) % block
    x = jnp.pad(planes, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _shuffle_kernel,
        out_shape=jax.ShapeDtypeStruct((itemsize, x.shape[0]), jnp.uint8),
        grid=(x.shape[0] // block,),
        in_specs=[pl.BlockSpec((block, itemsize), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((itemsize, block), lambda i: (0, i)),
        interpret=interpret,
    )(x)
    return out[:, :n]
