"""Pallas TPU kernel: RWKV-6 (Finch) wkv recurrence, chunked form.

Recurrence per (batch, head), state S in R^{Dk x Dv}:

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Data-dependent per-channel decay w_t makes this the hard case for
parallelization (vs Mamba-2's scalar decay).  The chunked formulation
processes T in chunks of C: the inter-chunk state S flows sequentially in
VMEM scratch across grid steps, while *within* a chunk the output is
computed in matmul form:

    o_t = (r_t . W_{t-1}) S_0  +  sum_{s<t} [sum_c r_tc k_sc e^{cw_{t-1,c}-cw_{s,c}}] v_s
          + (r_t . u . k_t) v_t

The pairwise per-channel decay ratio e^{cw[t-1]-cw[s]} is computed as a
masked (C, C, Dk) tensor — exponent <= 0 whenever s < t so it is
numerically safe for any decay magnitude (the naive q'=r*e^{cw},
k'=k*e^{-cw} factorization overflows for strong decay).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 32


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sout_ref, s_ref,
                  *, chunk: int, n_chunks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(jnp.float32)     # (C, Dk)
    k = k_ref[0, 0].astype(jnp.float32)     # (C, Dk)
    v = v_ref[0, 0].astype(jnp.float32)     # (C, Dv)
    w = w_ref[0, 0].astype(jnp.float32)     # (C, Dk) decay in (0,1)
    u = u_ref[0].astype(jnp.float32)        # (Dk,)
    S = s_ref[...]                          # (Dk, Dv)

    lw = jnp.log(w)
    cw = jnp.cumsum(lw, axis=0)             # (C, Dk) inclusive

    # state contribution: o_state[t] = (r_t * W_{t-1}) S0, W_{t-1}=e^{cw[t-1]}
    w_prev = jnp.exp(jnp.concatenate([jnp.zeros_like(cw[:1]), cw[:-1]], axis=0))
    o_state = jnp.dot(r * w_prev, S, preferred_element_type=jnp.float32)

    # intra-chunk: A[t,s] = sum_c r[t,c] k[s,c] e^{cw[t-1,c]-cw[s,c]} (s<t)
    cw_prev = jnp.concatenate([jnp.zeros_like(cw[:1]), cw[:-1]], axis=0)
    expo = cw_prev[:, None, :] - cw[None, :, :]          # (C, C, Dk)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    strict = s_idx < t_idx
    ratio = jnp.where(strict[:, :, None], jnp.exp(expo), 0.0)
    A = jnp.einsum("tc,sc,tsc->ts", r, k, ratio)
    A += jnp.where(s_idx == t_idx, jnp.dot(r * u[None, :], k.T), 0.0)
    o = o_state + jnp.dot(A, v, preferred_element_type=jnp.float32)

    # inter-chunk state: S_C = e^{cw[C-1]} . S0 + sum_s e^{cw[C-1]-cw[s]} k_s^T v_s
    w_all = jnp.exp(cw[-1])                                # (Dk,)
    k_scaled = k * jnp.exp(cw[-1][None, :] - cw)           # (C, Dk), expo <= 0
    s_ref[...] = w_all[:, None] * S + jnp.dot(
        k_scaled.T, v, preferred_element_type=jnp.float32
    )
    o_ref[0, 0] = o.astype(o_ref.dtype)

    @pl.when(it == n_chunks - 1)
    def _():
        sout_ref[0, 0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(
    r: jax.Array,    # (B, H, T, Dk)
    k: jax.Array,
    v: jax.Array,    # (B, H, T, Dv)
    w: jax.Array,    # (B, H, T, Dk)
    u: jax.Array,    # (H, Dk)
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
):
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nt = t // chunk
    kernel = functools.partial(_rwkv6_kernel, chunk=chunk, n_chunks=nt)
    spec = pl.BlockSpec((1, 1, chunk, dk), lambda b_, h_, it: (b_, h_, it, 0))
    vspec = pl.BlockSpec((1, 1, chunk, dv), lambda b_, h_, it: (b_, h_, it, 0))
    out, state = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b, h, t, dv), v.dtype),
            jax.ShapeDtypeStruct((b, h, dk, dv), jnp.float32),
        ),
        grid=(b, h, nt),
        in_specs=[
            spec, spec, vspec, spec,
            pl.BlockSpec((1, dk), lambda b_, h_, it: (h_, 0)),
        ],
        out_specs=(
            vspec,
            pl.BlockSpec((1, 1, dk, dv), lambda b_, h_, it: (b_, h_, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out, state
