"""Pallas TPU kernels for the framework's compute hot spots.

Columnar-encoding kernels (the paper's serialization path, DESIGN.md §3.3):
``offsets_scan``, ``byteshuffle``, ``delta_zigzag`` — and the read-side
fused decode chain ``decode_pages`` (DESIGN.md §9).

Model kernels: ``flash_attention``, ``decode_attention``, ``rwkv6_scan``,
``mamba2_ssd``.

Use via :mod:`repro.kernels.ops`; oracles live in :mod:`repro.kernels.ref`.
Submodules load lazily: ``repro.kernels.ops`` exposes the backend
dispatch (``KernelDispatch``) without importing jax, so the core write
and read paths can consult it at import time for free.
"""

import importlib

__all__ = ["ops", "ref", "decode_pages"]


def __getattr__(name):
    if name in __all__:
        return importlib.import_module("." + name, __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
