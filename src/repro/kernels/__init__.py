"""Pallas TPU kernels for the framework's compute hot spots.

Columnar-encoding kernels (the paper's serialization path, DESIGN.md §3.3):
``offsets_scan``, ``byteshuffle``, ``delta_zigzag``.

Model kernels: ``flash_attention``, ``decode_attention``, ``rwkv6_scan``,
``mamba2_ssd``.

Use via :mod:`repro.kernels.ops`; oracles live in :mod:`repro.kernels.ref`.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
