"""Pallas TPU kernels: the fused per-column page *decode* chain.

The read-side inverse of the write path's preconditioning kernels
(``byteshuffle_pages``, ``delta_zigzag``, ``offsets_scan``): stored page
bytes upload to the device ONCE and columns materialize directly as JAX
device arrays — no host unsplit, no host zigzag/delta pass, no host
offset integration (DESIGN.md §9).

Two kernels:

* :func:`unsplit_pages` — inverse page-batched byteshuffle,
  ``(P, itemsize, per) uint8 -> (P, per, itemsize) uint8``.  Bandwidth
  bound, same tiling as the forward kernel.
* :func:`decode_offset_pages` — the FUSED offset-column chain: split
  uint64 zigzag deltas (the on-disk ``delta+zigzag+split`` encoding with
  per-page delta restart) decode in one pass to int32 cluster-relative
  end offsets: byte-plane gather -> zigzag inverse -> blocked inclusive
  scan with an SMEM carry that resets at every page boundary.

Both run in 32-bit lanes: the read engine only dispatches an offset
column here when the cluster's element total is below 2**31 (known from
the cluster metadata before any byte is read), which makes the int32
offsets EXACT and leaves byte planes 4..7 of the stored uint64 all zero.
The jnp oracles live in :mod:`repro.kernels.ref`; the numpy ground truth
is ``repro.core.encoding.unprecondition_pages_into``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 2048


def _unsplit_kernel(x_ref, o_ref):
    # x block: (1, itemsize, BN) uint8 -> out block (1, BN, itemsize)
    o_ref[...] = jnp.swapaxes(x_ref[...], 1, 2)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def unsplit_pages(
    planes: jax.Array, block: int = DEFAULT_BLOCK, interpret: bool = False
) -> jax.Array:
    """(P, itemsize, per) uint8 -> (P, per, itemsize): inverse byteshuffle.

    Page ``p``'s byte planes land back as that page's contiguous
    little-endian elements in ``out[p]`` — the exact inverse of
    ``byteshuffle_pages``.  Blocks never cross page boundaries (a page is
    its own independent transpose).
    """
    n_pages, itemsize, per = planes.shape
    blk = min(block, per)
    pad = (-per) % blk
    x = jnp.pad(planes, ((0, 0), (0, 0), (0, pad)))
    out = pl.pallas_call(
        _unsplit_kernel,
        out_shape=jax.ShapeDtypeStruct(
            (n_pages, x.shape[2], itemsize), jnp.uint8
        ),
        grid=(n_pages, x.shape[2] // blk),
        in_specs=[pl.BlockSpec((1, itemsize, blk), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((1, blk, itemsize), lambda i, j: (i, j, 0)),
        interpret=interpret,
    )(x)
    return out[:, :per, :]


def _offsets_decode_kernel(x_ref, o_ref, carry_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        # per-page delta restart: the carry resets at every page start
        carry_ref[0] = jnp.zeros((), jnp.int32)

    x = x_ref[...]  # (1, 8, BN) uint8 byte planes of the stored uint64
    # low 32 bits only — the dispatch guard proves planes 4..7 are zero
    z = (
        x[0, 0].astype(jnp.uint32)
        | (x[0, 1].astype(jnp.uint32) << 8)
        | (x[0, 2].astype(jnp.uint32) << 16)
        | (x[0, 3].astype(jnp.uint32) << 24)
    )
    # zigzag inverse: (z >> 1) ^ -(z & 1); the logical shift happens in
    # uint32, the xor in int32 (magnitudes fit by the same guard)
    d = (z >> 1).astype(jnp.int32) ^ -(z & 1).astype(jnp.int32)
    o_ref[...] = (jnp.cumsum(d) + carry_ref[0])[None]
    carry_ref[0] = carry_ref[0] + jnp.sum(d)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def decode_offset_pages(
    planes: jax.Array, block: int = DEFAULT_BLOCK, interpret: bool = False
) -> jax.Array:
    """(P, 8, per) uint8 split zigzag deltas -> (P, per) int32 end offsets.

    The fused offset-column decode: one kernel launch per column replaces
    the host's unsplit + zigzag decode + per-page ``integrate_sizes``
    loop.  The grid walks (page, block-within-page); the scan carry lives
    in SMEM and resets at each page's first block (per-page delta
    restart), so pages integrate independently exactly like the numpy
    reference.
    """
    n_pages, itemsize, per = planes.shape
    assert itemsize == 8, "offset columns store uint64 planes"
    blk = min(block, per)
    pad = (-per) % blk
    x = jnp.pad(planes, ((0, 0), (0, 0), (0, pad)))
    out = pl.pallas_call(
        _offsets_decode_kernel,
        out_shape=jax.ShapeDtypeStruct((n_pages, x.shape[2]), jnp.int32),
        grid=(n_pages, x.shape[2] // blk),
        in_specs=[pl.BlockSpec((1, 8, blk), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((1, blk), lambda i, j: (i, j)),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(x)
    return out[:, :per]


# ---------------------------------------------------------------------------
# the device decode chain (jitted drivers used by the read engine)
#
# ``raw`` is a flat uint8 device array holding one column's stored page
# payloads in the sealed-cluster layout: page p of k <= per elements at
# byte range [p*per*itemsize, p*per*itemsize + k*itemsize).  The drivers
# below decode it to the column's element array entirely on device;
# ``use_pallas`` switches between the Pallas kernels and the jnp oracle
# ops (both run on the device — the oracle path is what "auto" compiles
# through XLA on CPU backends, the kernels engage on TPU or when forced).


def _tail_split(raw: jax.Array, head: int, n: int, nb: int) -> jax.Array:
    """Unsplit the final partial page ((nb, k) planes -> (k, nb) bytes)."""
    k = n - head
    t = jax.lax.dynamic_slice(raw, (head * nb,), (k * nb,))
    return jnp.swapaxes(t.reshape(nb, k), 0, 1)


def _bitcast_elems(rows: jax.Array, dtype) -> jax.Array:
    """(N, itemsize) uint8 little-endian rows -> (N,) dtype elements."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.uint8:
        return rows.reshape(-1)
    return jax.lax.bitcast_convert_type(rows, dtype)


@functools.partial(
    jax.jit, static_argnames=("n", "per", "dtype", "use_pallas", "interpret")
)
def device_decode_none(raw: jax.Array, n: int, per: int, dtype,
                       use_pallas: bool = False,
                       interpret: bool = False) -> jax.Array:
    """ENC_NONE: reinterpret the stored bytes as elements (pure bitcast)."""
    nb = jnp.dtype(dtype).itemsize
    return _bitcast_elems(raw[: n * nb].reshape(n, nb), dtype)


@functools.partial(
    jax.jit, static_argnames=("n", "per", "dtype", "use_pallas", "interpret")
)
def device_decode_split(raw: jax.Array, n: int, per: int, dtype,
                        use_pallas: bool = False,
                        interpret: bool = False) -> jax.Array:
    """ENC_SPLIT: page-batched inverse byteshuffle -> (n,) dtype elements."""
    from . import ref

    nb = jnp.dtype(dtype).itemsize
    n_full = n // per
    head = n_full * per
    parts = []
    if n_full:
        src = raw[: head * nb].reshape(n_full, nb, per)
        if use_pallas:
            rows = unsplit_pages(src, interpret=interpret)
        else:
            rows = ref.unsplit_pages_ref(src)
        parts.append(rows.reshape(head, nb))
    if head < n:
        parts.append(_tail_split(raw, head, n, nb))
    rows = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return _bitcast_elems(rows, dtype)


@functools.partial(
    jax.jit, static_argnames=("n", "per", "use_pallas", "interpret")
)
def device_decode_offsets(raw: jax.Array, n: int, per: int,
                          use_pallas: bool = False,
                          interpret: bool = False) -> jax.Array:
    """ENC_DELTA_ZIGZAG_SPLIT: fused decode to (n,) int32 end offsets.

    Exact (not approximate) under the reader's dispatch guard: every
    offset in the cluster is below 2**31, so the int32 device column is
    bit-identical to the int64 host reference after widening.
    """
    from . import ref

    n_full = n // per
    head = n_full * per
    parts = []
    if n_full:
        src = raw[: head * 8].reshape(n_full, 8, per)
        if use_pallas:
            offs = decode_offset_pages(src, interpret=interpret)
        else:
            offs = ref.decode_offset_pages_ref(src)
        parts.append(offs.reshape(head))
    if head < n:
        rows = _tail_split(raw, head, n, 8)  # (k, 8) uint8
        z = (
            rows[:, 0].astype(jnp.uint32)
            | (rows[:, 1].astype(jnp.uint32) << 8)
            | (rows[:, 2].astype(jnp.uint32) << 16)
            | (rows[:, 3].astype(jnp.uint32) << 24)
        )
        d = ref.unzigzag_ref(z)
        parts.append(jnp.cumsum(d))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)
