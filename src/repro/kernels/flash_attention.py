"""Pallas TPU flash attention (train / prefill).

Online-softmax tiled attention with GQA/MQA head grouping, causal masking
and optional sliding-window (SWA) masking.  Grid is
(batch, q_head, q_block, kv_block) with the kv dimension innermost —
sequential on a TensorCore — so the running (m, l, acc) statistics live in
VMEM scratch and are finalized on the last kv step.

Block sizes default to 128×128, MXU-aligned; head_dim is kept whole in
VMEM (D <= 256 -> at most 128·256·4 B = 128 KiB per operand tile).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: Optional[int],
    block_q: int, block_k: int, q_offset: int, n_kv_blocks: int,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (BK, D)

    iq = pl.program_id(2)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    s = jnp.dot(q * scale, k.T, preferred_element_type=jnp.float32)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_kv_blocks - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,                 # (B, H, Sq, D)
    k: jax.Array,                 # (B, G, Sk, D)
    v: jax.Array,                 # (B, G, Sk, D)
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, g, sk, _ = k.shape
    assert h % g == 0, (h, g)
    q_per_kv = h // g
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = qp.shape[2] // block_q
    nk = kp.shape[2] // block_k
    # Padded kv columns must stay masked: they sit at positions >= sk and a
    # causal mask with q_offset = sk - sq keeps every real q row below them
    # ... except the padded q rows, which we slice off anyway.  For the
    # non-causal case mask via window=None + explicit validity below.
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        q_offset=sk - sq,
        n_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h_, iq, ik, q_per_kv=q_per_kv: (b_, h_ // q_per_kv, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h_, iq, ik, q_per_kv=q_per_kv: (b_, h_ // q_per_kv, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :sq]
