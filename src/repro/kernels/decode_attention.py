"""Pallas TPU kernel: single-token KV-cache attention (decode).

One new query token per sequence attends to a long KV cache.  The cache is
streamed through VMEM in blocks along the sequence axis with online-softmax
accumulation; per-sequence valid ``length`` and optional sliding-window
masking make it usable for both dense decode (decode_32k) and SWA decode
(long_500k on mixtral-style models).

This kernel is memory-bound by design (arithmetic intensity ~2 flops/byte);
its role is to stream the cache at HBM bandwidth — see EXPERIMENTS §Roofline.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, window: Optional[int], block_k: int, n_kv_blocks: int,
):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (D,)
    k = k_ref[0, 0].astype(jnp.float32)            # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (BK, D)
    length = len_ref[0]

    pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)
    valid = pos < length
    if window is not None:
        valid &= pos >= length - window

    s = jnp.dot(k, q * scale, preferred_element_type=jnp.float32)  # (BK,)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p[None, :], v, preferred_element_type=jnp.float32
    )
    m_ref[0] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _():
        o_ref[0, 0] = (acc_ref[0] / jnp.maximum(l_ref[0], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "block_k", "interpret")
)
def decode_attention(
    q: jax.Array,                  # (B, H, D)
    k: jax.Array,                  # (B, G, S, D)
    v: jax.Array,                  # (B, G, S, D)
    length: Optional[jax.Array] = None,   # (B,) valid cache lengths
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, h, d = q.shape
    _, g, s, _ = k.shape
    q_per_kv = h // g
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))
    block_k = min(block_k, s)
    pad_k = (-s) % block_k
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nk = kp.shape[2] // block_k
    if length is None:
        length = jnp.full((b,), s, jnp.int32)
    length = length.astype(jnp.int32).reshape(b, 1)
    kernel = functools.partial(
        _decode_kernel,
        scale=scale,
        window=window,
        block_k=block_k,
        n_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        grid=(b, h, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h_, ik: (b_, 0)),
            pl.BlockSpec((1, 1, d), lambda b_, h_, ik: (b_, h_, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h_, ik, q_per_kv=q_per_kv: (b_, h_ // q_per_kv, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda b_, h_, ik, q_per_kv=q_per_kv: (b_, h_ // q_per_kv, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b_, h_, ik: (b_, h_, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(length, q, kp, vp)
    return out
