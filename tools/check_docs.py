"""Documentation dead-link check (CI `docs` job).

Walks the repo's markdown documents, extracts every markdown link and
verifies that relative targets exist on disk (external ``http(s)://``
links are left alone — CI must not depend on the network).  Anchored
links (``DESIGN.md#...``) check only the file part.  Also verifies the
inline-code file references of README.md's layout section exist.

Run:  python tools/check_docs.py [repo_root]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

DOCS = [
    "README.md",
    "DESIGN.md",
    "ROADMAP.md",
    "PAPER.md",
    "PAPERS.md",
    "CHANGES.md",
    "benchmarks/README.md",
]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check(root: Path) -> int:
    errors = []
    for doc in DOCS:
        path = root / doc
        if not path.exists():
            errors.append(f"{doc}: document missing")
            continue
        text = path.read_text(encoding="utf-8")
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            file_part = target.split("#", 1)[0]
            if not file_part:  # pure in-page anchor
                continue
            resolved = (path.parent / file_part).resolve()
            if not resolved.exists():
                errors.append(f"{doc}: dead link -> {target}")
    for err in errors:
        print(f"ERROR: {err}")
    if not errors:
        print(f"docs OK: {len(DOCS)} documents, all relative links resolve")
    return 1 if errors else 0


if __name__ == "__main__":
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parents[1]
    sys.exit(check(root))
