"""Chaos driver: run the write path under injected storage faults.

Each scenario builds a file through a :class:`FaultInjectingSink` and
asserts the robustness contract that DESIGN.md §8 promises for it:

* ``transient``     — scripted EIO/EAGAIN bursts + a torn (short) write:
                      the run completes, retry counters are nonzero, and
                      the file reads back with zero loss.
* ``seeded``        — seeded random transient errors at an error rate:
                      same seed → same fault schedule; zero loss.
* ``enospc``        — persistent ENOSPC on an offset window: retries
                      exhaust, the writer poisons, close() raises, and a
                      second close() is a safe no-op.
* ``fsync``         — transient then permanent fsync failure: the former
                      is retried, the latter poisons (never swallowed).
* ``stripe``        — a non-retryable stripe error: the engine rewrites
                      the extent monolithically and disables striping.
* ``ring``          — write-behind (emulated ring) under transient
                      faults: completes with zero loss.
* ``latency``       — injected latency spikes: slow but lossless.
* ``kill``          — a matrix of process-kill points across the file:
                      each torn file is salvaged by ``recover_container``
                      and every salvaged entry reads back byte-identical.
* ``skim``          — selective (zone-map pruned) filtered reads under
                      transient pread faults (retried, results equal the
                      clean run) and after a kill+recover (zone maps
                      dropped with a reason, filtered results exact).
* ``remote``        — the object-store sink cell matrix (DESIGN.md §10):
                      clean multipart byte-identity, transient transport
                      faults retried, seeded random faults, torn ranged
                      GETs, hedged slow tails, multipart→serial-put
                      degradation, and a writer killed mid-multipart
                      whose interrupted upload ``recover_container``
                      salvages back into a readable object.

Run:
    python tools/chaos.py                      # all scenarios
    python tools/chaos.py --scenario kill      # one scenario
    python tools/chaos.py --seed 3 --entries 2000

Exit status: 0 when every scenario holds its invariant, 1 otherwise.
"""

from __future__ import annotations

import argparse
import errno
import multiprocessing
import os
import sys
import tempfile
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    Collection,
    F,
    FaultInjectingSink,
    FaultSpec,
    FencedError,
    Leaf,
    MemorySink,
    MultiWriterCoordinator,
    ParallelWriter,
    ProcessKilled,
    RNTJReader,
    RetryPolicy,
    Schema,
    SequentialWriter,
    WriteOptions,
    join_container,
    open_sink,
    recover_container,
    RecoveryError,
)
from repro.core import FaultSchedule, ReadOptions  # noqa: E402
from repro.core.faults import crashed_file_bytes, memory_sink_from_bytes  # noqa: E402
from repro.core.remote import (  # noqa: E402
    FakeTransport,
    ObjectBucket,
    ObjectStoreSink,
    RemoteOptions,
    salvage_remote,
)

SCHEMA = Schema([
    Leaf("id", "int64"),
    Collection("vals", Leaf("_0", "float32")),
])

# fast deterministic backoff so chaos runs stay quick
POLICY = RetryPolicy(max_attempts=8, backoff_base=0.0002, backoff_cap=0.002)


def make_entries(n: int, seed: int):
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, 6, size=n)
    return [
        {"id": int(i), "vals": [float(v) for v in rng.random(lens[i],
                                                             dtype=np.float32)]}
        for i in range(n)
    ]


def write_through(sink, entries, **opt_kw):
    opts = WriteOptions(cluster_bytes=opt_kw.pop("cluster_bytes", 8192),
                        retry_policy=POLICY, **opt_kw)
    w = SequentialWriter(SCHEMA, sink, opts)
    for e in entries:
        w.fill(e)
    w.close()
    return w


def verify_lossless(inner_sink, entries, label):
    r = RNTJReader(inner_sink)
    got = list(r.iter_entries())
    r.close()
    assert len(got) == len(entries), (
        f"{label}: {len(got)} of {len(entries)} entries read back")
    assert got == entries, f"{label}: entries differ after faults"


# -- scenarios ---------------------------------------------------------------


def scenario_transient(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.transient_error(count=3),
        FaultSpec.transient_error(err=errno.EAGAIN, count=2, at_call=11),
        FaultSpec.short_write(at_call=6),
    ])
    w = write_through(fs, entries)
    d = w.stats.as_dict()
    assert d["io_retries"] >= 5, f"retries not counted: {d['io_retries']}"
    assert d["io_giveups"] == 0
    verify_lossless(fs.inner, entries, "transient")
    return {"retries": d["io_retries"], "injected": fs.faults.injected}


def scenario_seeded(entries, seed):
    # a 10% per-call rate needs enough write calls to fire with near
    # certainty — pad tiny --entries workloads deterministically
    if len(entries) < 2000:
        entries = entries + make_entries(2000 - len(entries), seed + 1)
    fs = FaultInjectingSink(MemorySink(), seed=seed, error_rate=0.1)
    w = write_through(fs, entries, cluster_bytes=2048)
    d = w.stats.as_dict()
    assert fs.faults.random_errors >= 1, "seeded schedule injected nothing"
    assert d["io_retries"] >= fs.faults.random_errors
    verify_lossless(fs.inner, entries, "seeded")
    return {"retries": d["io_retries"],
            "injected": fs.faults.random_errors}


def scenario_enospc(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec(op="write", kind="error", err=errno.ENOSPC, count=-1,
                  at_offset=(4096, 1 << 62)),
    ])
    w = SequentialWriter(SCHEMA, fs, WriteOptions(cluster_bytes=2048,
                                                  retry_policy=POLICY))
    poisoned = False
    try:
        for e in entries:
            w.fill(e)
        w.close()
    except (OSError, RuntimeError):
        poisoned = True
    assert poisoned, "persistent ENOSPC did not fail the writer"
    try:
        w.close()  # the first close after a poisoned commit surfaces it
    except (OSError, RuntimeError):
        pass
    w.close()      # ... and any further close is a safe no-op (§8.2)
    d = w.stats.as_dict()
    assert d["io_giveups"] >= 1, "exhausted retries not counted as giveup"
    return {"giveups": d["io_giveups"], "retries": d["io_retries"]}


def scenario_fsync(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [FaultSpec.fsync_error(count=2)])
    w = write_through(fs, entries, fsync_policy="every_cluster")
    assert w.stats.as_dict()["io_retries"] >= 2
    verify_lossless(fs.inner, entries, "fsync-transient")

    fs = FaultInjectingSink(MemorySink(), [FaultSpec.fsync_error(count=-1)])
    w = SequentialWriter(SCHEMA, fs, WriteOptions(
        cluster_bytes=8192, retry_policy=POLICY,
        fsync_policy="every_cluster"))
    poisoned = False
    try:
        for e in entries:
            w.fill(e)
        w.close()
    except (OSError, RuntimeError):
        poisoned = True
    try:
        w.close()
    except (OSError, RuntimeError):
        pass
    assert poisoned, "permanent fsync failure was swallowed"
    assert w.stats.as_dict()["io_fsync_failures"] >= 1
    return {"fsync_failures": w.stats.as_dict()["io_fsync_failures"]}


def scenario_stripe(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.transient_error(err=errno.EBADF, at_call=4, count=1),
    ])
    w = write_through(fs, entries, cluster_bytes=16384,
                      io_stripe_bytes=2048, io_workers=2)
    d = w.stats.as_dict()
    assert d["io_stripe_fallbacks"] >= 1, "stripe failure did not degrade"
    verify_lossless(fs.inner, entries, "stripe")
    return {"stripe_fallbacks": d["io_stripe_fallbacks"]}


def scenario_ring(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.transient_error(count=4),
    ])
    opts = WriteOptions(cluster_bytes=4096, retry_policy=POLICY,
                        io_inflight_bytes=1 << 20, io_ring=0)
    w = ParallelWriter(SCHEMA, fs, opts)
    ctx = w.create_fill_context()
    for e in entries:
        ctx.fill(e)
    ctx.close()
    w.close()
    d = w.stats.as_dict()
    assert d["io_retries"] >= 1
    verify_lossless(fs.inner, entries, "ring")
    return {"retries": d["io_retries"]}


def scenario_latency(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.latency(0.002, count=5),
    ])
    write_through(fs, entries)
    assert fs.faults.latencies == 5
    verify_lossless(fs.inner, entries, "latency")
    return {"latencies": fs.faults.latencies}


def scenario_kill(entries, seed):
    # reference file: the same workload written cleanly
    ref = MemorySink()
    write_through(ref, entries, cluster_bytes=2048)
    size = ref.size
    kills = [int(k) for k in np.linspace(200, size + 64, 12)]
    salvaged_total = 0
    results = []
    for K in kills:
        fs = FaultInjectingSink(MemorySink(), [FaultSpec.kill_at(K)])
        try:
            write_through(fs, entries, cluster_bytes=2048)
            crashed = False
        except (ProcessKilled, OSError, RuntimeError):
            crashed = True
        ms = memory_sink_from_bytes(crashed_file_bytes(fs))
        try:
            rep = recover_container(ms)
        except RecoveryError:
            assert K < 1024, f"header-only loss expected near 0, not K={K}"
            results.append((K, "unrecoverable"))
            continue
        r = RNTJReader(ms)
        got = list(r.iter_entries())
        r.close()
        assert got == entries[: len(got)], (
            f"K={K}: salvaged entries not byte-identical")
        if not crashed:
            assert len(got) == len(entries)
        salvaged_total += len(got)
        results.append((K, len(got)))
    return {"kill_points": len(kills), "salvage": results}


def scenario_skim(entries, seed):
    """Selective (zone-map pruned) reads under the §8.2 fault schedule.

    Three invariants: (a) a pruned filtered read through a faulty sink
    equals the clean pruned read (transient pread errors retried, not
    surfaced); (b) pruned ≡ unpruned on the same faulty sink; (c) a file
    torn by a mid-write kill recovers with its zone maps DROPPED (the
    journal cannot attest them) and the filtered read over the salvaged
    file is exact — stale bounds are never served.
    """
    pred = (F("vals._0") > 0.8) | (F("id") < 10)

    def filtered(sink, prune, retry=None):
        r = RNTJReader(sink, options=ReadOptions(filter=pred, prune=prune,
                                                 retry_policy=retry))
        try:
            return list(r.iter_filtered_entries()), r.stats
        finally:
            r.close()

    clean = MemorySink()
    write_through(clean, entries, cluster_bytes=2048,
                  page_size=512, codec="none")
    ref, ref_stats = filtered(clean, prune=True)
    full, _ = filtered(clean, prune=False)
    assert ref == full, "pruned filtered read differs from full scan"

    # (a)+(b) transient pread faults under the reader's retry policy
    fs = FaultInjectingSink(memory_sink_from_bytes(bytes(clean.buf[:clean.size])), [
        FaultSpec.transient_error(count=3, op="read"),
        FaultSpec.transient_error(err=errno.EAGAIN, count=2, op="read",
                                  at_call=5),
    ])
    got, stats = filtered(fs, prune=True, retry=POLICY)
    assert got == ref, "faulty-sink pruned read differs from clean read"
    assert stats.retries >= 1, "transient pread faults were not retried"

    # (c) kill mid-write, recover, re-filter: zone maps dropped, results exact
    fs = FaultInjectingSink(MemorySink(), [FaultSpec.kill_at(clean.size // 2)])
    try:
        write_through(fs, entries, cluster_bytes=2048,
                      page_size=512, codec="none")
    except (ProcessKilled, OSError, RuntimeError):
        pass
    ms = memory_sink_from_bytes(crashed_file_bytes(fs))
    rep = recover_container(ms)
    assert rep.rebuilt, "kill point did not tear the footer"
    assert rep.zonemaps is not None and not rep.zonemaps["preserved"], (
        "recovery must drop unattested zone maps with a reason")
    pruned, _ = filtered(ms, prune=True)
    unpruned, _ = filtered(ms, prune=False)
    assert pruned == unpruned, "salvaged file: pruned differs from full scan"
    n_match = len(pruned)
    return {"matched": len(ref), "retries": stats.retries,
            "salvaged_matches": n_match}


# -- multi-process crash matrix (DESIGN.md §8.6) -----------------------------

# WriteOptions for every mp cell: tiny clusters, fast leases, no side-car
# fsync (the matrix kills processes, not the kernel)
def _mp_options():
    return WriteOptions(cluster_bytes=2048, retry_policy=POLICY,
                        lease_interval=0.3, rendezvous_timeout=5.0,
                        mpw_log_fsync=False)


def _mp_fault_specs(fault: str, point: int):
    if fault == "eio":
        return [FaultSpec.transient_error(count=3)]
    if fault == "torn":
        return [FaultSpec.short_write(at_call=3)]
    if fault == "enospc":  # a persistent wall at this writer's Nth byte
        return [FaultSpec(op="write", kind="error", err=errno.ENOSPC,
                          count=-1, at_byte=point)]
    if fault == "fsync":
        return [FaultSpec.fsync_error(count=-1)]
    if fault == "kill":
        return [FaultSpec.kill_at(point)]
    return []


def _mp_chaos_worker(path, entries, fault, point):
    """Forked child: join the shared container with an injected fault.

    Exit codes: 0 clean DONE; 2 poisoned (fault surfaced, no DONE);
    3 process-killed mid-write; 4 fenced straggler correctly refused;
    5 fencing VIOLATED (a fenced writer's commit went through).
    """
    fs = FaultInjectingSink(open_sink(path, create=False),
                            _mp_fault_specs(fault, point))
    try:
        w = join_container(path, schema=SCHEMA, options=_mp_options(), sink=fs)
        ctx = w.create_fill_context()
        if fault == "straggler":
            half = len(entries) // 2
            for e in entries[:half]:
                ctx.fill(e)
            ctx.flush_cluster()
            time.sleep(point)  # sleep past the rendezvous deadline
            try:
                for e in entries[half:]:
                    ctx.fill(e)
                ctx.flush_cluster()
                os._exit(5)  # must be unreachable: we were fenced
            except (FencedError, RuntimeError, OSError):
                os._exit(4)
        for e in entries:
            ctx.fill(e)
        ctx.close()
        w.close()
    except ProcessKilled:
        os._exit(3)
    except (OSError, RuntimeError):
        os._exit(2)
    os._exit(0)


def _mp_run_cell(entries, n_writers, fault, point, rendezvous_timeout=None):
    """One matrix cell: N forked writers over one container; returns
    (salvaged entries in file order, per-writer slices, exitcodes, report,
    container path, tmpdir handle).  ``fault`` is one kind for every
    writer, or a per-writer list."""
    tmp = tempfile.TemporaryDirectory(prefix="rntj-chaos-")
    path = os.path.join(tmp.name, "mp.rntj")
    opts = _mp_options()
    chunk = (len(entries) + n_writers - 1) // n_writers
    slices = [entries[w * chunk: (w + 1) * chunk] for w in range(n_writers)]
    faults = fault if isinstance(fault, list) else [fault] * n_writers
    ctx = multiprocessing.get_context("fork")
    coord = MultiWriterCoordinator(SCHEMA, path, opts)
    procs = [ctx.Process(target=_mp_chaos_worker,
                         args=(path, slices[w], faults[w], point))
             for w in range(n_writers)]
    for p in procs:
        p.start()
    report = coord.seal(expect_writers=n_writers,
                        timeout=rendezvous_timeout)
    coord.close()
    for p in procs:
        p.join()
    exitcodes = [p.exitcode for p in procs]
    r = RNTJReader(path)
    got = list(r.iter_entries())
    r.close()
    return got, slices, exitcodes, report, path, tmp


def _mp_check_cell(got, slices, exitcodes, label):
    """The salvage contract for one cell: every clean writer's entries are
    all present; a crashed writer's surviving entries are a prefix of what
    it wrote; every salvaged entry is byte-identical to its source."""
    by_id = {e["id"]: e for s in slices for e in s}
    for e in got:
        assert e == by_id[e["id"]], f"{label}: salvaged entry differs"
    ids = [e["id"] for e in got]
    assert len(ids) == len(set(ids)), f"{label}: duplicate salvaged entries"
    for w, s in enumerate(slices):
        mine = [e for e in got if e["id"] in {x["id"] for x in s}]
        if exitcodes[w] == 0:
            assert mine == s, (
                f"{label}: clean writer {w} lost "
                f"{len(s) - len(mine)} of {len(s)} entries")
        else:
            assert mine == s[: len(mine)], (
                f"{label}: writer {w} salvage is not a prefix of its commits")
    # byte-level check: the salvaged set re-written single-writer must
    # decode identically (same codec path, same framing semantics)
    ref = MemorySink()
    write_through(ref, got, cluster_bytes=2048)
    rr = RNTJReader(ref)
    assert list(rr.iter_entries()) == got, (
        f"{label}: salvaged decode differs from single-writer reference")
    rr.close()


def scenario_mpkill(entries, seed):
    """N-process × kill-point × fault-type crash matrix through real
    multiprocessing workers sharing one container file."""
    cells = []
    for n in (2, 4):
        for fault in ("eio", "torn", "fsync"):
            cells.append((n, fault, 0))
        # points straddle the commit stream: before the first cluster
        # lands (total loss), mid-stream (partial salvage), past the end
        # (no fault fires — clean)
        for fault in ("enospc", "kill"):
            for point in (900, 1400, 3000):
                cells.append((n, fault, point))
    results = []
    for n, fault, point in cells:
        label = f"mpkill[N={n},{fault},@{point}]"
        got, slices, codes, report, path, tmp = _mp_run_cell(
            entries[: 160 * n], n, fault, point)
        with tmp:
            _mp_check_cell(got, slices, codes, label)
            if fault in ("eio", "torn"):  # retried to success: zero loss
                assert codes == [0] * n, f"{label}: {codes}"
                assert not report["fenced"], f"{label}: {report}"
            if fault == "fsync":  # fsync poison: DONE withheld, fenced
                assert all(c != 0 for c in codes), f"{label}: {codes}"
                assert len(report["fenced"]) == n, f"{label}: {report}"
            # a degraded seal keeps the side-car; cross-check recovery's
            # view of the sealed file (footer must already be valid)
            rep = recover_container(path, dry_run=True)
            assert rep.footer_valid, f"{label}: sealed footer invalid"
        results.append((f"N={n}", fault, point, len(got),
                        {"codes": codes, "fenced": report["fenced"]}))

    # fencing invariant: a straggler fenced mid-rendezvous can never
    # corrupt what the seal committed
    n = 2
    got, slices, codes, report, path, tmp = _mp_run_cell(
        entries[:320], n, ["none", "straggler"], 3, rendezvous_timeout=1.0)
    with tmp:
        sealed = got
        assert codes[0] == 0 and codes[1] == 4, (
            f"straggler: exit codes {codes} (4 = fenced write refused)")
        assert len(report["fenced"]) == 1, f"straggler: {report}"
        r = RNTJReader(path)   # re-read AFTER the straggler's late attempt
        assert list(r.iter_entries()) == sealed, (
            "straggler: sealed entries changed after a fenced write")
        r.close()
        rep = recover_container(path, dry_run=True)
        assert rep.footer_valid, "straggler: footer damaged by fenced writer"
    results.append(("N=2", "straggler", 3, len(sealed),
                    {"codes": codes, "fenced": report["fenced"]}))
    return {"cells": len(results), "matrix": results}


def scenario_mprecover(entries, seed):
    """Coordinator dies mid-rendezvous (no footer): recover_container
    rebuilds the file from the journal + side-car log alone."""
    tmp = tempfile.TemporaryDirectory(prefix="rntj-chaos-")
    with tmp:
        path = os.path.join(tmp.name, "mp.rntj")
        opts = _mp_options()
        n = 2
        chunk = (len(entries) + n - 1) // n
        slices = [entries[w * chunk: (w + 1) * chunk] for w in range(n)]
        ctx = multiprocessing.get_context("fork")
        coord = MultiWriterCoordinator(SCHEMA, path, opts)
        procs = [ctx.Process(target=_mp_chaos_worker,
                             args=(path, slices[w], "none", 0))
                 for w in range(n)]
        for p in procs:
            p.start()
        for p in procs:
            p.join()
        assert [p.exitcode for p in procs] == [0, 0]
        # coordinator "crashes" here: no seal, no footer — just drop it
        coord.sink.close()
        coord.log.close()
        rep = recover_container(path)
        assert not rep.footer_valid, "unsealed file cannot have a footer"
        assert rep.multiwriter is not None, "side-car state not consulted"
        r = RNTJReader(path)
        got = list(r.iter_entries())
        r.close()
        assert sorted(e["id"] for e in got) == sorted(
            e["id"] for s in slices for e in s), "recovery lost entries"
        by_id = {e["id"]: e for s in slices for e in s}
        assert all(e == by_id[e["id"]] for e in got), "recovered entry differs"
        return {"writers": n, "recovered": len(got),
                "clusters": rep.clusters_salvaged}


def scenario_remote(entries, seed):
    """The object-store cell matrix: every remote failure mode in one run."""
    ROPTS = RemoteOptions(part_bytes=1024, retry_policy=POLICY)

    def remote_write(transport, entries, **kw):
        s = ObjectStoreSink(transport, "chaos.rntj", ROPTS)
        return s, write_through(s, entries, **kw)

    def remote_verify(bucket, entries, label):
        verify_lossless(
            ObjectStoreSink(FakeTransport(bucket), "chaos.rntj",
                            create=False),
            entries, label)

    info = {}

    # cell: clean multipart is byte-identical to the local reference
    ms = MemorySink()
    write_through(ms, entries)
    ref = bytes(ms.buf[: ms.size])
    ms.close()
    t = FakeTransport(ObjectBucket())
    s, w = remote_write(t, entries)
    s.close()
    assert t.bucket.objects["chaos.rntj"] == ref, "remote bytes differ"
    assert w.stats.as_dict()["io_retries"] == 0
    info["object_bytes"] = len(ref)

    # cell: scripted transient part/put faults are retried, zero loss
    sched = FaultSchedule([
        FaultSpec.transient_error(op="part", count=3),
        FaultSpec(op="part", kind="short", count=1, fraction=0.5),
    ])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    s, w = remote_write(t, entries)
    s.close()
    d = w.stats.as_dict()
    assert d["io_retries"] >= 4, f"transport retries: {d['io_retries']}"
    assert d["io_degradations"] == 0
    assert t.bucket.objects["chaos.rntj"] == ref
    info["transient_retries"] = d["io_retries"]

    # cell: seeded random transport faults — same seed, same schedule.
    # Transport ops are per-part (far fewer than per-pwrite), so a tiny
    # --entries workload is padded and the rate is high enough that the
    # schedule fires for any seed with near certainty.
    seeded_entries = entries
    if len(seeded_entries) < 2000:
        seeded_entries = entries + make_entries(2000 - len(entries),
                                                seed + 1)
    sched = FaultSchedule(seed=seed, error_rate=0.35,
                          errnos=(errno.EIO, errno.ETIMEDOUT),
                          random_ops=("put", "part", "get"))
    t = FakeTransport(ObjectBucket(), schedule=sched)
    s, w = remote_write(t, seeded_entries)
    s.close()
    d = w.stats.as_dict()
    assert sched.stats.random_errors >= 1, "seeded schedule injected nothing"
    assert d["io_retries"] + d["io_degradations"] >= 1
    remote_verify(t.bucket, seeded_entries, "remote-seeded")
    info["seeded_injected"] = sched.stats.random_errors

    # cell: torn ranged GETs + reader-level retry policy
    sched = FaultSchedule([
        FaultSpec.short_read(op="get", count=2, fraction=0.5),
        FaultSpec.transient_error(op="get", count=2),
    ])
    bkt = ObjectBucket()
    bkt.objects["chaos.rntj"] = ref
    rs = ObjectStoreSink(FakeTransport(bkt, schedule=sched), "chaos.rntj",
                         RemoteOptions(retry_policy=POLICY), create=False)
    r = RNTJReader(rs, options=ReadOptions(retry_policy=POLICY))
    got = list(r.iter_entries())
    r.close()
    assert got == entries, "torn/faulty GETs lost entries"
    d = r.stats.as_dict()
    assert d["io_retries"] >= 2, "transport-level read retries not counted"
    info["read_retries"] = d["io_retries"]

    # cell: hedged slow tail — scripted latency on the first GET only
    sched = FaultSchedule([FaultSpec.latency(0.2, op="get", count=1)])
    bkt = ObjectBucket()
    bkt.objects["chaos.rntj"] = ref
    rs = ObjectStoreSink(FakeTransport(bkt, schedule=sched), "chaos.rntj",
                         RemoteOptions(retry_policy=POLICY, hedge_ms=10),
                         create=False)
    r = RNTJReader(rs)
    got = list(r.iter_entries())
    r.close()
    assert got == entries
    d = r.stats.as_dict()
    assert d["io_hedges"] >= 1 and d["io_hedge_wins"] >= 1, (
        f"hedge did not win the race: {d['io_hedges']}/{d['io_hedge_wins']}")
    info["hedge_wins"] = d["io_hedge_wins"]

    # cell: permanent part failure degrades multipart -> serial put
    sched = FaultSchedule([FaultSpec.permanent_error(op="part")])
    t = FakeTransport(ObjectBucket(), schedule=sched)
    s, w = remote_write(t, entries)
    s.close()
    d = w.stats.as_dict()
    assert d["io_degradations"] >= 1, "degradation not counted"
    assert t.bucket.objects["chaos.rntj"] == ref, "degraded put lost bytes"
    info["degradations"] = d["io_degradations"]

    # cell: writer killed mid-multipart -> salvage the interrupted upload
    sched = FaultSchedule([FaultSpec(op="part", kind="kill", at_call=4)])
    bkt = ObjectBucket()
    s = ObjectStoreSink(FakeTransport(bkt, schedule=sched), "chaos.rntj",
                        ROPTS)
    killed = False
    try:
        write_through(s, entries, cluster_bytes=2048)
    except (ProcessKilled, RuntimeError):
        killed = True
    s.close()
    assert killed, "kill point never fired"
    assert "chaos.rntj" not in bkt.objects
    rep = salvage_remote(FakeTransport(bkt), "chaos.rntj")
    assert rep.remote["mode"] == "multipart"
    assert rep.rebuilt and rep.entries_salvaged > 0
    r = RNTJReader(ObjectStoreSink(FakeTransport(bkt), "chaos.rntj",
                                   create=False))
    got = list(r.iter_entries())
    r.close()
    assert got == entries[: len(got)], "salvaged entries differ"
    assert len(got) == rep.entries_salvaged
    info["salvaged_entries"] = rep.entries_salvaged
    return info


SCENARIOS = {
    "transient": scenario_transient,
    "seeded": scenario_seeded,
    "enospc": scenario_enospc,
    "fsync": scenario_fsync,
    "stripe": scenario_stripe,
    "ring": scenario_ring,
    "latency": scenario_latency,
    "kill": scenario_kill,
    "skim": scenario_skim,
    "mpkill": scenario_mpkill,
    "mprecover": scenario_mprecover,
    "remote": scenario_remote,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="RNT-J chaos scenarios")
    ap.add_argument("--scenario", default="all",
                    choices=["all"] + sorted(SCENARIOS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--entries", type=int, default=800)
    args = ap.parse_args(argv)

    names = sorted(SCENARIOS) if args.scenario == "all" else [args.scenario]
    entries = make_entries(args.entries, args.seed)
    failed = []
    for name in names:
        try:
            info = SCENARIOS[name](list(entries), args.seed)
        except AssertionError as e:
            print(f"FAIL {name}: {e}")
            failed.append(name)
            continue
        print(f"ok   {name}: {info}")
    if failed:
        print(f"{len(failed)} scenario(s) failed: {', '.join(failed)}")
        return 1
    print(f"all {len(names)} scenario(s) held their invariants")
    return 0


if __name__ == "__main__":
    sys.exit(main())
