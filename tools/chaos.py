"""Chaos driver: run the write path under injected storage faults.

Each scenario builds a file through a :class:`FaultInjectingSink` and
asserts the robustness contract that DESIGN.md §8 promises for it:

* ``transient``     — scripted EIO/EAGAIN bursts + a torn (short) write:
                      the run completes, retry counters are nonzero, and
                      the file reads back with zero loss.
* ``seeded``        — seeded random transient errors at an error rate:
                      same seed → same fault schedule; zero loss.
* ``enospc``        — persistent ENOSPC on an offset window: retries
                      exhaust, the writer poisons, close() raises, and a
                      second close() is a safe no-op.
* ``fsync``         — transient then permanent fsync failure: the former
                      is retried, the latter poisons (never swallowed).
* ``stripe``        — a non-retryable stripe error: the engine rewrites
                      the extent monolithically and disables striping.
* ``ring``          — write-behind (emulated ring) under transient
                      faults: completes with zero loss.
* ``latency``       — injected latency spikes: slow but lossless.
* ``kill``          — a matrix of process-kill points across the file:
                      each torn file is salvaged by ``recover_container``
                      and every salvaged entry reads back byte-identical.

Run:
    python tools/chaos.py                      # all scenarios
    python tools/chaos.py --scenario kill      # one scenario
    python tools/chaos.py --seed 3 --entries 2000

Exit status: 0 when every scenario holds its invariant, 1 otherwise.
"""

from __future__ import annotations

import argparse
import errno
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    Collection,
    FaultInjectingSink,
    FaultSpec,
    Leaf,
    MemorySink,
    ParallelWriter,
    ProcessKilled,
    RNTJReader,
    RetryPolicy,
    Schema,
    SequentialWriter,
    WriteOptions,
    recover_container,
    RecoveryError,
)
from repro.core.faults import crashed_file_bytes, memory_sink_from_bytes  # noqa: E402

SCHEMA = Schema([
    Leaf("id", "int64"),
    Collection("vals", Leaf("_0", "float32")),
])

# fast deterministic backoff so chaos runs stay quick
POLICY = RetryPolicy(max_attempts=8, backoff_base=0.0002, backoff_cap=0.002)


def make_entries(n: int, seed: int):
    rng = np.random.default_rng(seed)
    lens = rng.integers(0, 6, size=n)
    return [
        {"id": int(i), "vals": [float(v) for v in rng.random(lens[i],
                                                             dtype=np.float32)]}
        for i in range(n)
    ]


def write_through(sink, entries, **opt_kw):
    opts = WriteOptions(cluster_bytes=opt_kw.pop("cluster_bytes", 8192),
                        retry_policy=POLICY, **opt_kw)
    w = SequentialWriter(SCHEMA, sink, opts)
    for e in entries:
        w.fill(e)
    w.close()
    return w


def verify_lossless(inner_sink, entries, label):
    r = RNTJReader(inner_sink)
    got = list(r.iter_entries())
    r.close()
    assert len(got) == len(entries), (
        f"{label}: {len(got)} of {len(entries)} entries read back")
    assert got == entries, f"{label}: entries differ after faults"


# -- scenarios ---------------------------------------------------------------


def scenario_transient(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.transient_error(count=3),
        FaultSpec.transient_error(err=errno.EAGAIN, count=2, at_call=11),
        FaultSpec.short_write(at_call=6),
    ])
    w = write_through(fs, entries)
    d = w.stats.as_dict()
    assert d["io_retries"] >= 5, f"retries not counted: {d['io_retries']}"
    assert d["io_giveups"] == 0
    verify_lossless(fs.inner, entries, "transient")
    return {"retries": d["io_retries"], "injected": fs.faults.injected}


def scenario_seeded(entries, seed):
    # a 10% per-call rate needs enough write calls to fire with near
    # certainty — pad tiny --entries workloads deterministically
    if len(entries) < 2000:
        entries = entries + make_entries(2000 - len(entries), seed + 1)
    fs = FaultInjectingSink(MemorySink(), seed=seed, error_rate=0.1)
    w = write_through(fs, entries, cluster_bytes=2048)
    d = w.stats.as_dict()
    assert fs.faults.random_errors >= 1, "seeded schedule injected nothing"
    assert d["io_retries"] >= fs.faults.random_errors
    verify_lossless(fs.inner, entries, "seeded")
    return {"retries": d["io_retries"],
            "injected": fs.faults.random_errors}


def scenario_enospc(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec(op="write", kind="error", err=errno.ENOSPC, count=-1,
                  at_offset=(4096, 1 << 62)),
    ])
    w = SequentialWriter(SCHEMA, fs, WriteOptions(cluster_bytes=2048,
                                                  retry_policy=POLICY))
    poisoned = False
    try:
        for e in entries:
            w.fill(e)
        w.close()
    except (OSError, RuntimeError):
        poisoned = True
    assert poisoned, "persistent ENOSPC did not fail the writer"
    try:
        w.close()  # the first close after a poisoned commit surfaces it
    except (OSError, RuntimeError):
        pass
    w.close()      # ... and any further close is a safe no-op (§8.2)
    d = w.stats.as_dict()
    assert d["io_giveups"] >= 1, "exhausted retries not counted as giveup"
    return {"giveups": d["io_giveups"], "retries": d["io_retries"]}


def scenario_fsync(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [FaultSpec.fsync_error(count=2)])
    w = write_through(fs, entries, fsync_policy="every_cluster")
    assert w.stats.as_dict()["io_retries"] >= 2
    verify_lossless(fs.inner, entries, "fsync-transient")

    fs = FaultInjectingSink(MemorySink(), [FaultSpec.fsync_error(count=-1)])
    w = SequentialWriter(SCHEMA, fs, WriteOptions(
        cluster_bytes=8192, retry_policy=POLICY,
        fsync_policy="every_cluster"))
    poisoned = False
    try:
        for e in entries:
            w.fill(e)
        w.close()
    except (OSError, RuntimeError):
        poisoned = True
    try:
        w.close()
    except (OSError, RuntimeError):
        pass
    assert poisoned, "permanent fsync failure was swallowed"
    assert w.stats.as_dict()["io_fsync_failures"] >= 1
    return {"fsync_failures": w.stats.as_dict()["io_fsync_failures"]}


def scenario_stripe(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.transient_error(err=errno.EBADF, at_call=4, count=1),
    ])
    w = write_through(fs, entries, cluster_bytes=16384,
                      io_stripe_bytes=2048, io_workers=2)
    d = w.stats.as_dict()
    assert d["io_stripe_fallbacks"] >= 1, "stripe failure did not degrade"
    verify_lossless(fs.inner, entries, "stripe")
    return {"stripe_fallbacks": d["io_stripe_fallbacks"]}


def scenario_ring(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.transient_error(count=4),
    ])
    opts = WriteOptions(cluster_bytes=4096, retry_policy=POLICY,
                        io_inflight_bytes=1 << 20, io_ring=0)
    w = ParallelWriter(SCHEMA, fs, opts)
    ctx = w.create_fill_context()
    for e in entries:
        ctx.fill(e)
    ctx.close()
    w.close()
    d = w.stats.as_dict()
    assert d["io_retries"] >= 1
    verify_lossless(fs.inner, entries, "ring")
    return {"retries": d["io_retries"]}


def scenario_latency(entries, seed):
    fs = FaultInjectingSink(MemorySink(), [
        FaultSpec.latency(0.002, count=5),
    ])
    write_through(fs, entries)
    assert fs.faults.latencies == 5
    verify_lossless(fs.inner, entries, "latency")
    return {"latencies": fs.faults.latencies}


def scenario_kill(entries, seed):
    # reference file: the same workload written cleanly
    ref = MemorySink()
    write_through(ref, entries, cluster_bytes=2048)
    size = ref.size
    kills = [int(k) for k in np.linspace(200, size + 64, 12)]
    salvaged_total = 0
    results = []
    for K in kills:
        fs = FaultInjectingSink(MemorySink(), [FaultSpec.kill_at(K)])
        try:
            write_through(fs, entries, cluster_bytes=2048)
            crashed = False
        except (ProcessKilled, OSError, RuntimeError):
            crashed = True
        ms = memory_sink_from_bytes(crashed_file_bytes(fs))
        try:
            rep = recover_container(ms)
        except RecoveryError:
            assert K < 1024, f"header-only loss expected near 0, not K={K}"
            results.append((K, "unrecoverable"))
            continue
        r = RNTJReader(ms)
        got = list(r.iter_entries())
        r.close()
        assert got == entries[: len(got)], (
            f"K={K}: salvaged entries not byte-identical")
        if not crashed:
            assert len(got) == len(entries)
        salvaged_total += len(got)
        results.append((K, len(got)))
    return {"kill_points": len(kills), "salvage": results}


SCENARIOS = {
    "transient": scenario_transient,
    "seeded": scenario_seeded,
    "enospc": scenario_enospc,
    "fsync": scenario_fsync,
    "stripe": scenario_stripe,
    "ring": scenario_ring,
    "latency": scenario_latency,
    "kill": scenario_kill,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="RNT-J chaos scenarios")
    ap.add_argument("--scenario", default="all",
                    choices=["all"] + sorted(SCENARIOS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--entries", type=int, default=800)
    args = ap.parse_args(argv)

    names = sorted(SCENARIOS) if args.scenario == "all" else [args.scenario]
    entries = make_entries(args.entries, args.seed)
    failed = []
    for name in names:
        try:
            info = SCENARIOS[name](list(entries), args.seed)
        except AssertionError as e:
            print(f"FAIL {name}: {e}")
            failed.append(name)
            continue
        print(f"ok   {name}: {info}")
    if failed:
        print(f"{len(failed)} scenario(s) failed: {', '.join(failed)}")
        return 1
    print(f"all {len(names)} scenario(s) held their invariants")
    return 0


if __name__ == "__main__":
    sys.exit(main())
