"""Salvage a torn RNT-J file: scan its commit journal and rebuild the footer.

The writing process died before finalization (or the footer region is
corrupt): the anchor/footer/page-list chain is missing, so the normal
reader refuses the file — even though every committed cluster's bytes are
intact.  This tool runs :func:`repro.core.recover.recover_container` over
the file: it walks the data region's cluster envelopes + journal records,
validates page checksums, drops torn/corrupt clusters, and appends a
fresh page list + footer + anchor covering exactly what survived.  The
file then opens normally and every salvaged entry reads back
byte-identically.

Run:
    python tools/recover.py FILE            # recover in place
    python tools/recover.py FILE -o OUT     # recover a copy, leave FILE alone
    python tools/recover.py FILE --dry-run  # report what would be salvaged

Exit status: 0 when the file is healthy or was rebuilt, 1 when it cannot
be salvaged (e.g. the header itself is torn), 2 on bad usage.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.core import RecoveryError, recover_container  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="salvage a torn RNT-J file from its commit journal"
    )
    ap.add_argument("file", help="the (possibly torn) RNT-J file")
    ap.add_argument("-o", "--output", default=None,
                    help="write the recovered file here instead of in place")
    ap.add_argument("--dry-run", action="store_true",
                    help="scan and report only; write nothing")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip per-page checksum validation (faster, riskier)")
    ap.add_argument("--force", action="store_true",
                    help="rebuild even when the existing footer is valid")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="print the full report as JSON")
    args = ap.parse_args(argv)

    try:
        report = recover_container(
            args.file,
            output=args.output,
            dry_run=args.dry_run,
            verify_pages=not args.no_verify,
            force=args.force,
        )
    except RecoveryError as e:
        print(f"unrecoverable: {e}", file=sys.stderr)
        return 1

    if args.as_json:
        print(json.dumps(report.as_dict(), indent=2))
        return 0
    if report.footer_valid:
        print(f"{args.file}: footer chain valid "
              f"({report.entries_salvaged} entries) — nothing to do"
              " (use --force to rebuild anyway)")
        return 0
    verb = "would salvage" if args.dry_run else "salvaged"
    where = args.output or args.file
    print(f"{where}: {verb} {report.clusters_salvaged} clusters / "
          f"{report.entries_salvaged} entries "
          f"(dropped {len(report.clusters_dropped)}, "
          f"journal records {report.journal_records}, "
          f"resyncs {report.resyncs}, "
          f"scanned {report.scan_bytes} bytes "
          f"in {report.scan_seconds * 1e3:.1f} ms)")
    for d in report.clusters_dropped:
        print(f"  dropped cluster seq={d['seq']}: {d['reason']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
