"""§Perf hillclimbing driver: baseline vs variant dry-runs per cell.

For a chosen (arch, shape) cell, runs the dry-run for the paper-faithful
baseline and each requested variant, and reports the three roofline terms
side by side — the measurement half of the hypothesis → change → measure →
validate loop recorded in EXPERIMENTS.md §Perf.

Run:
  PYTHONPATH=src python -m benchmarks.perf_pass \
      --arch smollm-360m --shape train_4k \
      --variant chunked-attn --variant dp-wide
"""

from __future__ import annotations

import argparse
import json

from repro.launch import dryrun
from repro.launch.hlo_analysis import PEAK_FLOPS


def term_row(rec):
    r = rec["roofline"]
    t_dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
    useful = rec["model_flops_per_device"] / PEAK_FLOPS
    return {
        "compute_s": r["compute_s"],
        "memory_s": r["memory_s"],
        "collective_s": r["collective_s"],
        "dominant": r["dominant"],
        "roofline_frac": useful / t_dom if t_dom else None,
        "temp_gb": (rec["memory"]["temp_bytes"] or 0) / 2**30,
    }


def compare(arch: str, shape: str, variants, multi_pod=False, force=False):
    rows = {}
    base = dryrun.run_one(arch, shape, multi_pod, force=force)
    assert base["status"] == "ok", base
    rows["baseline"] = term_row(base)
    for v in variants:
        rec = dryrun.run_one(arch, shape, multi_pod, force=force, variant=v)
        rows[v] = (term_row(rec) if rec["status"] == "ok"
                   else {"error": rec.get("error", rec["status"])})
    return rows


def print_table(arch, shape, rows):
    print(f"\n=== {arch} x {shape} ===")
    print(f"{'variant':<16s}"
          f"{'compute_s':>11s}{'memory_s':>11s}{'coll_s':>9s}"
          f"{'dominant':>11s}{'frac':>7s}{'temp GiB':>9s}")
    for name, r in rows.items():
        if "error" in r:
            print(f"{name:<16s}  ERROR: {r['error'][:80]}")
            continue
        print(f"{name:<16s}{r['compute_s']:11.4f}{r['memory_s']:11.4f}"
              f"{r['collective_s']:9.4f}{r['dominant']:>11s}"
              f"{r['roofline_frac']:7.3f}{r['temp_gb']:9.1f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", action="append", default=[])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    rows = compare(args.arch, args.shape, args.variant, args.multi_pod,
                   args.force)
    print_table(args.arch, args.shape, rows)


if __name__ == "__main__":
    main()
