"""Zone-map pruning benchmark — the selective-read trajectory (DESIGN.md §11).

A selectivity sweep over the paper's synthetic nested-event workload
(``{id: int64, vals: float32[k]}``, monotonic ``id``): a deterministic
single-threaded **filtered-copy job** (read the entries matching
``F("id").between(...)``, refill them into an output file) runs twice
per cell — once with zone-map pruning, once with ``prune=False`` (the
full scan) — at selectivities 0.1%/1%/10%/50% and unfiltered, for codec
none and zlib.  Three invariants per cell, asserted not just reported:

 * the pruned and unpruned output files are **byte-identical** — the
   prune plan changes when bytes are read, never what is written;
 * the output stays readable by the vendored **seed reader**
   (``_legacy_seed_reader.py``) with identical arrays — zone maps ride
   in ``footer.extra``, invisible to pre-zone-map readers;
 * the pruned run reads **no more pages** than the unpruned run.

The headline metric is the pruned/unpruned speedup at ≤1% selectivity
(the acceptance floor is 3×; the sweep reports every cell).

Emits ``BENCH_skim.json`` (repo root by default).  Scratch files live in
``benchmarks/_scratch_skim/`` (gitignored) and are removed on exit.

Run:  PYTHONPATH=src python benchmarks/bench_skim.py [--quick]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import shutil
import time
from pathlib import Path

import numpy as np

from _harness import REPO_ROOT  # noqa: F401

from repro.core import (  # noqa: E402
    Collection,
    ColumnBatch,
    F,
    KIND_OFFSET,
    Leaf,
    RNTJReader,
    ReadOptions,
    Schema,
    SequentialWriter,
    WriteOptions,
)
from repro.core.encoding import offsets_to_sizes  # noqa: E402

from _legacy_seed_reader import SeedRNTJReader  # noqa: E402

SCRATCH = REPO_ROOT / "benchmarks" / "_scratch_skim"

SCHEMA = Schema([
    Leaf("id", "int64"),
    Collection("vals", Leaf("_0", "float32")),
])

# many pages per column and many clusters per file, so sub-file pruning
# has real granularity to work with
WRITE_KW = dict(page_size=4096, cluster_bytes=256 * 1024, level=1)

SELECTIVITIES = (0.001, 0.01, 0.1, 0.5, None)
CODECS = ("none", "zlib")


def build_input(path: Path, n: int, codec: str) -> None:
    rng = np.random.default_rng(12)
    opts = WriteOptions(codec=codec, **WRITE_KW)
    with SequentialWriter(SCHEMA, str(path), opts) as w:
        step = 8192
        for a in range(0, n, step):
            b = min(a + step, n)
            sizes = rng.poisson(5, b - a).astype(np.int64)
            w.fill_batch(ColumnBatch.from_arrays(SCHEMA, b - a, {
                "id": np.arange(a, b, dtype=np.int64),
                "vals": sizes,
                "vals._0": rng.uniform(0, 100, int(sizes.sum()))
                              .astype(np.float32),
            }))


def filtered_copy(in_path: Path, out_path: Path, expr, prune: bool,
                  codec: str):
    """The deterministic single-threaded copy job: read matching entries,
    refill them into ``out_path``.  Returns (wall seconds, reader stats,
    matched entries)."""
    ropts = ReadOptions(filter=expr, prune=prune)
    r = RNTJReader(str(in_path), options=ropts)
    w = SequentialWriter(SCHEMA, str(out_path),
                         WriteOptions(codec=codec, **WRITE_KW))
    matched = 0
    t0 = time.perf_counter()
    try:
        if expr is None:
            seg_iter = ((cols, n) for _i, segs in r.iter_cluster_segments()
                        for _e0, cols, n in segs)
        else:
            seg_iter = ((cols, n) for _i, _a0, cols, n in r.iter_filtered())
        for cols, n in seg_iter:
            data = {
                ci: (offsets_to_sizes(arr)
                     if r.schema.columns[ci].kind == KIND_OFFSET else arr)
                for ci, arr in cols.items()
            }
            w.fill_batch(ColumnBatch(r.schema, n, data))
            matched += n
    finally:
        w.close()
        wall = time.perf_counter() - t0
        stats = r.stats
        r.close()
    return wall, stats, matched


def _sha(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def seed_reader_ok(path: Path) -> bool:
    """The vendored pre-zone-map reader must see identical arrays."""
    new, old = RNTJReader(str(path)), SeedRNTJReader(str(path))
    try:
        if old.n_clusters != len(new.clusters):
            return False
        for i in range(old.n_clusters):
            a, b = new.read_cluster(i), old.read_cluster(i)
            for ci in a:
                if not np.array_equal(a[ci], b[ci]):
                    return False
        return True
    finally:
        new.close()
        old.close()


def run_cell(in_path: Path, n: int, sel, codec: str, repeats: int) -> dict:
    if sel is None:
        expr = None
    else:
        hi = max(int(n * sel) - 1, 0)
        expr = F("id").between(0, hi)
    best = {True: float("inf"), False: float("inf")}
    stats = {}
    matched = {}
    for _ in range(repeats):
        for prune in (True, False):
            out = SCRATCH / f"out_{'p' if prune else 'f'}.rntj"
            wall, st, m = filtered_copy(in_path, out, expr, prune, codec)
            if wall < best[prune]:
                best[prune] = wall
                stats[prune] = st
                matched[prune] = m
    p_out = SCRATCH / "out_p.rntj"
    f_out = SCRATCH / "out_f.rntj"
    identical = _sha(p_out) == _sha(f_out)
    seed_ok = seed_reader_ok(p_out)
    cell = {
        "selectivity": sel,
        "codec": codec,
        "matched": matched[True],
        "pruned_s": round(best[True], 4),
        "unpruned_s": round(best[False], 4),
        "speedup": round(best[False] / best[True], 2) if best[True] else None,
        "byte_identical": identical,
        "seed_reader_ok": seed_ok,
        "pages_read_pruned": stats[True].pages,
        "pages_read_unpruned": stats[False].pages,
        "clusters_pruned": stats[True].clusters_pruned,
    }
    assert matched[True] == matched[False], f"match counts differ: {cell}"
    assert identical, f"outputs not byte-identical: {cell}"
    assert seed_ok, f"seed reader disagrees on the output: {cell}"
    assert stats[True].pages <= stats[False].pages, (
        f"pruned path read more pages: {cell}")
    return cell


def run(n: int, repeats: int, quick: bool, out_path: Path) -> dict:
    SCRATCH.mkdir(parents=True, exist_ok=True)
    try:
        cells = []
        for codec in CODECS:
            in_path = SCRATCH / f"input_{codec}.rntj"
            build_input(in_path, n, codec)
            for sel in SELECTIVITIES:
                cell = run_cell(in_path, n, sel, codec, repeats)
                cells.append(cell)
                print(f"  sel={str(sel):6s} codec={codec:4s} "
                      f"pruned={cell['pruned_s']:.4f}s "
                      f"unpruned={cell['unpruned_s']:.4f}s "
                      f"speedup={cell['speedup']}x "
                      f"identical={cell['byte_identical']}")
        low_sel = [c for c in cells if c["selectivity"] is not None
                   and c["selectivity"] <= 0.01]
        floor = min(c["speedup"] for c in low_sel)
        ok = floor >= 3.0
        out = {
            "workload": {"events": n, "schema": "id:int64, vals:float32[k]",
                         **WRITE_KW, "repeats": repeats, "quick": quick},
            "cells": cells,
            "acceptance": {
                "min_speedup_at_le_1pct": floor,
                "floor": 3.0,
                "ok": ok,
                "byte_identical_all": all(c["byte_identical"] for c in cells),
                "seed_reader_ok_all": all(c["seed_reader_ok"] for c in cells),
            },
        }
        out_path.write_text(json.dumps(out, indent=1))
        print(f"wrote {out_path}  (>=3x at <=1%: {ok}, floor {floor}x)")
        if not quick:
            assert ok, f"speedup floor not met: {floor}x < 3x"
        return out
    finally:
        shutil.rmtree(SCRATCH, ignore_errors=True)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="small workload, single repeat (CI smoke)")
    ap.add_argument("--events", type=int, default=0)
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_skim.json"))
    args = ap.parse_args()
    n = args.events or (60_000 if args.quick else 400_000)
    repeats = 1 if args.quick else 2
    run(n, repeats, args.quick, Path(args.out))


if __name__ == "__main__":
    main()
