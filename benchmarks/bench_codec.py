"""Codec-engine benchmark — seal+read throughput across the codec matrix.

Measures, on a nested schema with int64 / float64 / float32 columns:

 1. a **codec matrix** — none / zlib / lzma × split preconditioning
    on/off × framed chunking on/off: single-producer fill+seal
    throughput, cluster-read throughput, file size, and per-column
    compressed bytes.  Every cell asserts a byte-exact round trip
    (split + chunked pages decode to identical arrays, checksums
    verified) and the chunked-zlib cell is cross-checked through the
    vendored page-at-a-time seed reader — framed members and adaptive
    per-page codecs stay readable by the unmodified legacy path.
 2. the **zlib-gap closure** — the paper's uniform (incompressible
    floats) workload at zlib, PR 1 engine knobs (pooled + pipelined,
    no chunking, no adaptive policy) vs the codec engine (chunked
    members + adaptive per-column fallback to raw storage).  The
    incompressible float column samples at ~0.84 ratio and ~10 MB/s
    deflate; the policy drops it to ``CODEC_NONE`` (as ROOT does) while
    the id/offset columns keep their ~0.01-0.07 ratios — this is the
    direct fix for PR 1's 1.3-1.7x zlib gap.
 3. the **split-encoding gain** — per-column compressed bytes at zlib,
    split on vs off, for the int64 and float64 columns.

Emits ``BENCH_codec.json`` (repo root by default).  Scratch files live
in ``benchmarks/_scratch_codec/`` (gitignored) and are removed on exit.

Run:  PYTHONPATH=src python benchmarks/bench_codec.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from _harness import (  # noqa: F401
    REPO_ROOT, prebuild, probe_parallel_capacity,
)

from repro.core import (  # noqa: E402
    Collection, ColumnBatch, DevNullSink, Leaf, RNTJReader, ReadOptions,
    Schema, SequentialWriter, WriteOptions,
)

from _legacy_seed_reader import SeedRNTJReader  # noqa: E402

SCRATCH = REPO_ROOT / "benchmarks" / "_scratch_codec"

# int64 timestamps + float64 energies + nested float32 hits: the columns
# split preconditioning is supposed to win on (paper §3 / ROOT's split
# encoding), with detector-style value distributions
CODEC_SCHEMA = Schema([
    Leaf("t", "int64"),
    Leaf("e", "float64"),
    Collection("hits", Leaf("_0", "float32")),
])


def codec_batch(rng: np.random.Generator, n: int, id0: int = 0) -> ColumnBatch:
    t = (np.arange(id0, id0 + n, dtype=np.int64) * 40_000
         + rng.integers(0, 25_000, n))
    e = np.round(rng.gamma(2.0, 15.0, n) * 64) / 64            # float64
    sizes = rng.poisson(5, n).astype(np.int64)
    hits = (np.round(rng.gamma(2.0, 15.0, int(sizes.sum())) * 64) / 64
            ).astype(np.float32)
    return ColumnBatch.from_arrays(CODEC_SCHEMA, n, {
        "t": t, "e": e, "hits": sizes, "hits._0": hits,
    })


def prebuild_codec(entries: int, per_batch: int = 50_000) -> List[ColumnBatch]:
    rng = np.random.default_rng(0)
    out, done = [], 0
    while done < entries:
        n = min(per_batch, entries - done)
        out.append(codec_batch(rng, n, id0=done))
        done += n
    return out


def expected_columns(batches: List[ColumnBatch]) -> Dict[str, np.ndarray]:
    """Global (whole-file) per-column reference arrays for verification."""
    exp: Dict[str, np.ndarray] = {}
    for col in CODEC_SCHEMA.columns:
        parts = [b.data[col.index] for b in batches]
        arr = np.concatenate(parts)
        if col.kind == 1:  # offset column: sizes -> global end offsets
            arr = np.cumsum(arr)
        exp[col.path] = arr
    return exp


def write_file(path, batches, opts: WriteOptions) -> float:
    t0 = time.perf_counter()
    with SequentialWriter(CODEC_SCHEMA, str(path), opts) as w:
        for b in batches:
            w.fill_batch(b)
    return time.perf_counter() - t0


def fill_seal_devnull(schema, batches, opts: WriteOptions, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        w = SequentialWriter(schema, DevNullSink(), opts)
        t0 = time.perf_counter()
        for b in batches:
            w.fill_batch(b)
        w.close()
        best = min(best, time.perf_counter() - t0)
    return best


def fill_seal_interleaved(schema, batches, configs: Dict[str, WriteOptions],
                          repeats: int) -> Dict[str, float]:
    """Best-of-N fill+seal walls with the configs interleaved per round,
    so slow drift on a shared container cancels out of their ratio."""
    walls = {name: float("inf") for name in configs}
    for _ in range(repeats):
        for name, opts in configs.items():
            w = SequentialWriter(schema, DevNullSink(), opts)
            t0 = time.perf_counter()
            for b in batches:
                w.fill_batch(b)
            w.close()
            walls[name] = min(walls[name], time.perf_counter() - t0)
    return walls


def read_and_verify(path, expected: Dict[str, np.ndarray], repeats: int) -> float:
    """Best-of cluster-read wall; asserts byte-exact decoded columns."""
    best = float("inf")
    for _ in range(repeats):
        r = RNTJReader(str(path), options=ReadOptions(decode_workers=2))
        t0 = time.perf_counter()
        got = {p: r.read_column(p) for p in expected}
        best = min(best, time.perf_counter() - t0)
        r.close()
        for p, arr in expected.items():
            if not np.array_equal(got[p], arr):
                raise SystemExit(f"round-trip mismatch on column {p!r}")
    return best


def per_column_compressed(path) -> Dict[str, dict]:
    """Stored payload bytes per column, from the page list."""
    r = RNTJReader(str(path))
    out: Dict[str, dict] = {
        c.path: {"bytes": 0, "pages": 0, "codecs": set()} for c in r.schema.columns
    }
    for cm in r.clusters:
        for p in cm.pages:
            rec = out[r.schema.columns[p.column].path]
            rec["bytes"] += p.size
            rec["pages"] += 1
            rec["codecs"].add(p.codec)
    r.close()
    for rec in out.values():
        rec["codecs"] = sorted(rec["codecs"])
    return out


def seed_reader_crosscheck(path, expected: Dict[str, np.ndarray]) -> None:
    """The unmodified page-at-a-time legacy read path must decode files
    written with chunked members and adaptive per-page codecs: every
    cluster through the seed reader must match the read engine exactly."""
    seed = SeedRNTJReader(str(path))
    engine = RNTJReader(str(path))
    try:
        for ci in range(engine.n_clusters):
            a, b = seed.read_cluster(ci), engine.read_cluster(ci)
            for k in b:
                if not np.array_equal(a[k], b[k]):
                    raise SystemExit(
                        f"seed reader mismatch: cluster {ci}, column {k}"
                    )
    finally:
        seed.close()
        engine.close()


# ---------------------------------------------------------------------------
# 1. the codec matrix


def run_matrix(entries: int, repeats: int, workers: int, out: dict) -> None:
    print("== codec matrix: seal+read at none/zlib/lzma x split x chunking ==")
    page_size = 256 * 1024
    chunk = 64 * 1024
    batches = prebuild_codec(entries)
    expected = expected_columns(batches)
    nbytes = sum(sum(a.nbytes for a in b.data.values()) for b in batches)
    out["matrix"] = []
    out["matrix_uncompressed_mb"] = round(nbytes / 1e6, 1)
    for codec in ("none", "zlib", "lzma"):
        for split in (True, False):
            for chunked in (True, False):
                if codec == "none" and chunked:
                    continue  # no entropy coder: nothing to frame
                opts = WriteOptions(
                    codec=codec, level=-1, page_size=page_size,
                    cluster_bytes=2 * 1024 * 1024, imt_workers=workers,
                    pipelined_seal=True, precondition=split,
                    codec_chunk_bytes=chunk if chunked else 0,
                )
                path = SCRATCH / f"m_{codec}_s{int(split)}_c{int(chunked)}.rntj"
                seal_wall = fill_seal_devnull(CODEC_SCHEMA, batches, opts,
                                              repeats)
                write_file(path, batches, opts)
                read_wall = read_and_verify(path, expected, repeats)
                cols = per_column_compressed(path)
                rec = {
                    "codec": codec, "split": split, "chunked": chunked,
                    "seal_wall_s": round(seal_wall, 4),
                    "seal_mb_s": round(nbytes / seal_wall / 1e6, 1),
                    "read_wall_s": round(read_wall, 4),
                    "read_mb_s": round(nbytes / read_wall / 1e6, 1),
                    "file_mb": round(os.path.getsize(path) / 1e6, 2),
                    "columns": cols,
                    "verified": True,
                }
                out["matrix"].append(rec)
                print(f"  {codec:5s} split={int(split)} chunk={int(chunked)}"
                      f"  seal {rec['seal_mb_s']:7.1f} MB/s"
                      f"  read {rec['read_mb_s']:7.1f} MB/s"
                      f"  file {rec['file_mb']:6.2f} MB")
                if codec == "zlib" and split and chunked:
                    seed_reader_crosscheck(path, expected)
                    rec["legacy_reader_verified"] = True
                    print("        legacy page-at-a-time reader: verified")

    # split-encoding gain on the int64/float64 columns at zlib (unchunked)
    def cell(split):
        return next(r for r in out["matrix"]
                    if r["codec"] == "zlib" and r["split"] == split
                    and not r["chunked"])

    s_on, s_off = cell(True), cell(False)
    out["split_gain_zlib"] = {
        path: {
            "split_bytes": s_on["columns"][path]["bytes"],
            "nosplit_bytes": s_off["columns"][path]["bytes"],
            "reduction": round(
                1 - s_on["columns"][path]["bytes"]
                / max(1, s_off["columns"][path]["bytes"]), 3),
        }
        for path in ("t", "e", "hits._0")
    }
    for path, g in out["split_gain_zlib"].items():
        print(f"  split gain {path:8s}: {g['nosplit_bytes']:>9d} -> "
              f"{g['split_bytes']:>9d} bytes ({g['reduction']:.1%} smaller)")


# ---------------------------------------------------------------------------
# 2. zlib-gap closure vs the PR 1 engine


def run_zlib_gap(entries: int, repeats: int, workers: int, out: dict) -> None:
    print("== zlib gap: PR 1 engine vs codec engine (uniform workload) ==")
    batches = prebuild("uniform", entries, 50_000)
    nbytes = sum(sum(a.nbytes for a in b.data.values()) for b in batches)
    from _harness import EVENT_SCHEMA

    pr1 = WriteOptions(codec="zlib", level=1, page_size=64 * 1024,
                       cluster_bytes=1 << 20, imt_workers=workers,
                       pipelined_seal=True, codec_chunk_bytes=0,
                       adaptive_codec=False)
    engine = WriteOptions(codec="zlib", level=1, page_size=64 * 1024,
                          cluster_bytes=1 << 20, imt_workers=workers,
                          pipelined_seal=True, codec_chunk_bytes=64 * 1024,
                          adaptive_codec=True, adaptive_sample_pages=4,
                          adaptive_threshold=0.8)
    walls = fill_seal_interleaved(EVENT_SCHEMA, batches,
                                  {"pr1": pr1, "engine": engine}, repeats)
    pr1_wall, engine_wall = walls["pr1"], walls["engine"]

    # verify the adaptive file round-trips byte-exactly and record the
    # per-codec attribution of the final configuration
    path = SCRATCH / "zlib_gap_engine.rntj"
    w = SequentialWriter(EVENT_SCHEMA, str(path), engine)
    for b in batches:
        w.fill_batch(b)
    w.close()
    exp: Dict[str, np.ndarray] = {}
    for col in EVENT_SCHEMA.columns:
        arr = np.concatenate([b.data[col.index] for b in batches])
        exp[col.path] = np.cumsum(arr) if col.kind == 1 else arr
    read_and_verify(path, exp, 1)
    per_codec = {k: dict(v) for k, v in w.stats.as_dict()["per_codec"].items()}

    speedup = pr1_wall / engine_wall
    out["zlib_gap"] = {
        "workload": "uniform (incompressible floats, paper synthetic)",
        "pr1": {"wall_s": round(pr1_wall, 4),
                "mb_s": round(nbytes / pr1_wall / 1e6, 1)},
        "engine": {"wall_s": round(engine_wall, 4),
                   "mb_s": round(nbytes / engine_wall / 1e6, 1),
                   "adaptive_threshold": engine.adaptive_threshold,
                   "chunk_bytes": engine.codec_chunk_bytes,
                   "per_codec": per_codec},
        "speedup_vs_pr1": round(speedup, 3),
        "round_trip_verified": True,
    }
    out["speedup_zlib_vs_pr1"] = round(speedup, 3)
    print(f"  pr1 engine  {nbytes / pr1_wall / 1e6:8.1f} MB/s")
    print(f"  codec engine{nbytes / engine_wall / 1e6:8.1f} MB/s  "
          f"({speedup:.2f}x)")

    # the compressible workload for honesty: the policy must KEEP zlib
    hep = prebuild("hep", entries, 50_000)
    hep_nbytes = sum(sum(a.nbytes for a in b.data.values()) for b in hep)
    hw = fill_seal_interleaved(EVENT_SCHEMA, hep,
                               {"pr1": pr1, "engine": engine}, repeats)
    hep_pr1, hep_eng = hw["pr1"], hw["engine"]
    out["zlib_gap_hep"] = {
        "pr1_mb_s": round(hep_nbytes / hep_pr1 / 1e6, 1),
        "engine_mb_s": round(hep_nbytes / hep_eng / 1e6, 1),
        "speedup_vs_pr1": round(hep_pr1 / hep_eng, 3),
    }
    print(f"  hep workload: pr1 {hep_nbytes / hep_pr1 / 1e6:.1f} MB/s -> "
          f"engine {hep_nbytes / hep_eng / 1e6:.1f} MB/s "
          f"({hep_pr1 / hep_eng:.2f}x; policy keeps zlib)")


def run(entries: int, quick: bool, out_path: Path) -> dict:
    SCRATCH.mkdir(parents=True, exist_ok=True)
    repeats = 2 if quick else 4
    workers = min(4, max(2, (os.cpu_count() or 2)))
    out: dict = {
        "benchmark": "bench_codec",
        "schema": "event{t:int64, e:float64, hits:float32[k~Poisson(5)]}",
        "entries": entries,
        "cpu_count": os.cpu_count(),
        "imt_workers": workers,
        "parallel_capacity_2t": probe_parallel_capacity(),
    }
    print(f"parallel capacity probe (2-thread zlib scaling): "
          f"{out['parallel_capacity_2t']}x of ideal 2.0")
    try:
        run_matrix(entries, repeats, workers, out)
        run_zlib_gap(entries, repeats, workers, out)
    finally:
        shutil.rmtree(SCRATCH, ignore_errors=True)
    out_path.write_text(json.dumps(out, indent=1))
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--entries", type=int, default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small workload for CI smoke runs")
    ap.add_argument("--out", type=str,
                    default=str(REPO_ROOT / "BENCH_codec.json"))
    args = ap.parse_args()
    entries = args.entries or (60_000 if args.quick else 300_000)
    run(entries, args.quick, Path(args.out))


if __name__ == "__main__":
    main()
