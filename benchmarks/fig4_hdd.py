"""Paper Fig. 4: synthetic benchmark against an HDD bandwidth limit.

fio limit on the paper's Toshiba MG07ACA: 217 MB/s.  The paper finds the
uncompressed configuration saturates ~180 MB/s at TWO threads already and
compression reaches ~191 MB/s at high thread counts; fallocate makes no
difference on the HDD.  Same methodology as fig3: calibrated simulation
against the device model (plus a slow real ThrottledSink validation point
reused from fig3).

Run:  PYTHONPATH=src:. python -m benchmarks.fig4_hdd
"""

from __future__ import annotations

import json
from pathlib import Path

from .calibrate import calibrate
from .simulate import Costs, Device, simulate

RESULTS = Path(__file__).parent / "results"

HDD_BW = 217e6


def run(full: bool = True) -> dict:
    out = {"projected": []}
    costs = calibrate(200_000)
    uncomp = Costs(**{**costs.__dict__, "compression_ratio": 1.0,
                      "seal_s_per_byte": costs.seal_s_per_byte * 0.12})
    device = Device(bw=HDD_BW)
    sims = {
        "zlib-buffered": dict(costs=costs, buffered=True),
        "zlib-unbuffered": dict(costs=costs, buffered=False),
        "uncompressed": dict(costs=uncomp, buffered=True),
    }
    threads = [1, 2, 4, 8, 16, 32, 64, 128] if full else [1, 64]
    print(f"{'config':18s} " + " ".join(f"{t:>7d}" for t in threads))
    for name, kw in sims.items():
        row = []
        for n in threads:
            r = simulate(n, 12, device=device, n_cores=64, **kw)
            row.append(r.bandwidth_compressed / 1e6)
            out["projected"].append(
                {"config": name, "threads": n, "mb_s": row[-1]})
        print(f"{name:18s} " + " ".join(f"{x:7.0f}" for x in row))

    unc = [p for p in out["projected"] if p["config"] == "uncompressed"]
    at2 = next(p["mb_s"] for p in unc if p["threads"] == 2)
    out["uncompressed_at_2t_frac"] = at2 / (HDD_BW / 1e6)
    print(f"uncompressed @2t = {at2:.0f} MB/s = "
          f"{out['uncompressed_at_2t_frac']:.0%} of the 217 MB/s limit "
          f"(paper: ~83% at 2 threads)")

    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "fig4_hdd.json").write_text(json.dumps(out, indent=1))
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
