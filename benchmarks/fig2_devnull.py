"""Paper Fig. 2: weak scaling of the synthetic benchmark into /dev/null.

Two parts:
 1. MEASURED (this container, 1 core): real multithreaded runs at 1-4
    threads — correctness, bandwidths, and the lock-count reproduction of
    the paper's futex diagnosis (buffered ~1 acquisition/cluster vs
    unbuffered ~1/page: two orders of magnitude, paper §6.1).
 2. PROJECTED (calibrated simulator, 64 cores / 128 SMT threads like the
    paper's EPYC 7702P): weak-scaling curves for buffered / unbuffered /
    separate-writers / uncompressed, to compare against the paper's
    45.4x @ 64t (buffered zstd), unbuffered collapse, 27.1x uncompressed.

Run:  PYTHONPATH=src:. python -m benchmarks.fig2_devnull [--entries 200000]
"""

from __future__ import annotations

import argparse
import json
import threading
import time
from pathlib import Path

import numpy as np

from repro.core import DevNullSink, ParallelWriter, WriteOptions

from .calibrate import EVENT_SCHEMA, calibrate, synth_batch
from .simulate import Costs, Device, simulate

RESULTS = Path(__file__).parent / "results"


def measured_run(n_threads: int, entries_per_thread: int,
                 options: WriteOptions, independent: bool = False):
    """Real threads writing the paper's synthetic data to /dev/null."""
    def make_writer():
        return ParallelWriter(EVENT_SCHEMA, DevNullSink(), options)

    writers = ([make_writer() for _ in range(n_threads)] if independent
               else [make_writer()])
    t0 = time.perf_counter()

    def worker(tid: int):
        w = writers[tid] if independent else writers[0]
        rng = np.random.default_rng(tid)
        ctx = w.create_fill_context()
        done = 0
        while done < entries_per_thread:
            n = min(100_000, entries_per_thread - done)
            ctx.fill_batch(synth_batch(rng, n, id0=tid * 10**9 + done))
            done += n
        ctx.close()

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for w in writers:
        w.close()
    wall = time.perf_counter() - t0
    agg = {"uncompressed_bytes": 0, "compressed_bytes": 0,
           "lock_acquisitions": 0, "lock_contended": 0, "lock_held_ms": 0.0,
           "fill_ms": 0.0, "seal_ms": 0.0, "compress_ms": 0.0,
           "commit_ms": 0.0, "io_ms": 0.0}
    for w in writers:
        d = w.stats.as_dict()
        for k in agg:
            agg[k] += d[k]
    return wall, agg


def run(entries: int, full_sim: bool = True) -> dict:
    out = {"measured": [], "projected": [], "calibration": None}

    print("== measured (1-core container) ==")
    configs = {
        "buffered": WriteOptions(codec="zlib", level=1),
        "unbuffered": WriteOptions(codec="zlib", level=1, buffered=False),
        "uncompressed": WriteOptions(codec="none"),
        "buffered+opt2": WriteOptions(codec="zlib", level=1,
                                      write_outside_lock=True),
    }
    for name, opts in configs.items():
        for n in (1, 2, 4):
            wall, agg = measured_run(n, entries, opts)
            rec = {
                "config": name, "threads": n, "wall_s": round(wall, 3),
                "mb_s_uncompressed": agg["uncompressed_bytes"] / wall / 1e6,
                "mb_s_compressed": agg["compressed_bytes"] / wall / 1e6,
                "lock_acquisitions": agg["lock_acquisitions"],
                "lock_contended": agg["lock_contended"],
                "lock_held_frac": agg["lock_held_ms"] / 1e3 / wall,
                # per-phase breakdown (summed over producers): where the
                # write path actually spends its time
                "phases_ms": {
                    "fill": round(agg["fill_ms"], 1),
                    "seal": round(agg["seal_ms"], 1),
                    "compress": round(agg["compress_ms"], 1),
                    "commit": round(agg["commit_ms"], 1),
                    "io": round(agg["io_ms"], 1),
                },
            }
            out["measured"].append(rec)
            ph = rec["phases_ms"]
            print(f"  {name:14s} t={n}  {rec['mb_s_uncompressed']:7.1f} MB/s "
                  f"locks={rec['lock_acquisitions']:6d} "
                  f"contended={rec['lock_contended']:5d} "
                  f"held={rec['lock_held_frac']:.2%}  "
                  f"phases[fill={ph['fill']:.0f} seal={ph['seal']:.0f} "
                  f"compress={ph['compress']:.0f} commit={ph['commit']:.0f} "
                  f"io={ph['io']:.0f} ms]")

    # the futex-diagnosis reproduction (paper: ~300 vs >27,000 at 64t)
    buf = [r for r in out["measured"] if r["config"] == "buffered"][-1]
    unb = [r for r in out["measured"] if r["config"] == "unbuffered"][-1]
    out["lock_ratio"] = unb["lock_acquisitions"] / max(buf["lock_acquisitions"], 1)
    print(f"  lock-acquisition ratio unbuffered/buffered: "
          f"{out['lock_ratio']:.0f}x  (paper: ~90x via futex counts)")

    print("== projected (calibrated 64-core simulation) ==")
    costs = calibrate(max(entries, 200_000))
    out["calibration"] = costs.__dict__
    clusters = 24  # per thread (weak scaling)
    uncomp = Costs(**{**costs.__dict__, "compression_ratio": 1.0,
                      "seal_s_per_byte": costs.seal_s_per_byte * 0.12})
    sims = {
        "buffered": dict(costs=costs, buffered=True),
        "unbuffered": dict(costs=costs, buffered=False),
        "separate-writers": dict(costs=costs, buffered=True,
                                 independent_writers=True),
        "uncompressed": dict(costs=uncomp, buffered=True),
    }
    base = {}
    threads = [1, 2, 4, 8, 16, 32, 64, 128] if full_sim else [1, 64]
    for name, kw in sims.items():
        for n in threads:
            r = simulate(n, clusters, device=Device(), n_cores=64, **kw)
            rec = {
                "config": name, "threads": n,
                "mb_s_compressed": r.bandwidth_compressed / 1e6,
                "mb_s_uncompressed": r.bandwidth_uncompressed / 1e6,
                "lock_acquisitions": r.lock_acquisitions,
                "lock_wait_s": round(r.lock_wait_s, 4),
            }
            out["projected"].append(rec)
            if n == 1:
                base[name] = r.bandwidth_compressed
        last = [x for x in out["projected"] if x["config"] == name]
        s64 = next(x for x in last if x["threads"] == 64)
        speedup = s64["mb_s_compressed"] * 1e6 / base[name]
        print(f"  {name:17s} 64t speedup {speedup:5.1f}x "
              f"({s64['mb_s_compressed']:8.1f} MB/s compressed, "
              f"{s64['mb_s_uncompressed']:8.1f} MB/s uncompressed)")
        out.setdefault("speedup_64t", {})[name] = speedup

    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "fig2_devnull.json").write_text(json.dumps(out, indent=1))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--entries", type=int, default=200_000)
    args = ap.parse_args()
    run(args.entries)


if __name__ == "__main__":
    main()
