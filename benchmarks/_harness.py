"""Shared benchmark harness for the RNT-J perf-trajectory benches.

Everything the writer/reader/codec benchmarks previously duplicated:
``sys.path`` bootstrap, the paper's synthetic nested-event workloads
(incompressible uniform floats and detector-style quantized values),
workload prebuilding (RNG cost stays out of the timings), the runtime
*parallel-capacity probe* (measured 2-thread zlib scaling — pooled/
pipelined speedups are bounded by it, and shared CI containers often
expose far less than ``cpu_count`` suggests), and file building.
"""

from __future__ import annotations

import sys
import threading
import time
import zlib
from pathlib import Path
from typing import Callable, Dict, List

import numpy as np

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))
if str(REPO_ROOT / "benchmarks") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "benchmarks"))

from repro.core import (  # noqa: E402
    Collection, ColumnBatch, Leaf, Schema, SequentialWriter, WriteOptions,
)

EVENT_SCHEMA = Schema([
    Leaf("id", "int64"),
    Collection("vals", Leaf("_0", "float32")),
])


def synth_batch(rng: np.random.Generator, n: int, id0: int = 0) -> ColumnBatch:
    """The paper's synthetic events: incompressible uniform floats."""
    sizes = rng.poisson(5, n).astype(np.int64)
    vals = rng.uniform(0, 100, int(sizes.sum())).astype(np.float32)
    return ColumnBatch.from_arrays(
        EVENT_SCHEMA, n,
        {"id": np.arange(id0, id0 + n), "vals": sizes, "vals._0": vals},
    )


def hep_batch(rng: np.random.Generator, n: int, id0: int = 0) -> ColumnBatch:
    """Detector-style values: limited dynamic range, 1/64 quantization —
    compresses like real physics data rather than white noise."""
    sizes = rng.poisson(5, n).astype(np.int64)
    vals = (rng.gamma(2.0, 15.0, int(sizes.sum())).astype(np.float32) * 64)
    vals = (np.round(vals) / 64).astype(np.float32)
    return ColumnBatch.from_arrays(
        EVENT_SCHEMA, n,
        {"id": np.arange(id0, id0 + n), "vals": sizes, "vals._0": vals},
    )


WORKLOADS: Dict[str, Callable] = {"uniform": synth_batch, "hep": hep_batch}


def prebuild(workload: str, entries: int, batch_entries: int) -> List[ColumnBatch]:
    """Generate the workload up front so RNG cost stays out of the timing."""
    make = WORKLOADS[workload]
    rng = np.random.default_rng(0)
    batches, done = [], 0
    while done < entries:
        n = min(batch_entries, entries - done)
        batches.append(make(rng, n, id0=done))
        done += n
    return batches


def probe_parallel_capacity() -> float:
    """Measured 2-thread zlib scaling on THIS machine right now.

    1.0 means no parallel headroom (single effective core / noisy box);
    2.0 means two full cores.  Pool/pipeline gains are bounded by this.
    """
    rng = np.random.default_rng(7)
    page = rng.uniform(0, 100, 16384).astype(np.float32).tobytes()

    def work(n):
        for _ in range(n):
            zlib.compress(page, 1)

    t0 = time.perf_counter()
    work(60)
    serial = time.perf_counter() - t0
    ts = [threading.Thread(target=work, args=(30,)) for _ in range(2)]
    t0 = time.perf_counter()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    par = time.perf_counter() - t0
    return round(serial / par, 2)


def build_file(path, entries: int, codec: str, level: int,
               options: WriteOptions = None, schema: Schema = None,
               workload: str = "uniform") -> int:
    """Write a synthetic workload file; returns its uncompressed byte size."""
    schema = schema or EVENT_SCHEMA
    opts = options or WriteOptions(codec=codec, level=level,
                                   cluster_bytes=1 << 20, page_size=64 * 1024)
    make = WORKLOADS[workload]
    rng = np.random.default_rng(0)
    nbytes = 0
    with SequentialWriter(schema, str(path), opts) as w:
        done = 0
        while done < entries:
            n = min(50_000, entries - done)
            batch = make(rng, n, id0=done)
            nbytes += sum(a.nbytes for a in batch.data.values())
            w.fill_batch(batch)
            done += n
    return nbytes
