"""Object-store sink benchmark — remote fill+seal vs the local ceiling.

The cell matrix crosses simulated transport conditions (RTT × bandwidth ×
transient-fault rate, all through :class:`FakeTransport`'s shared
latency model) with two writer configurations:

* ``sync``        — the synchronous commit path over one connection:
                    every completed part upload blocks the committing
                    thread for a full round trip, so wall time collapses
                    toward ``n_parts × RTT``;
* ``writebehind`` — the emulated-ring write-behind engine
                    (``io_ring="emulated"``) + ``remote_parallel_connections``:
                    part uploads overlap each other and the fill, which
                    should hold fill+seal throughput near the local
                    (MemorySink) ceiling until bandwidth, not latency,
                    binds.

Every no-fault cell must produce an object byte-identical to the local
reference (seed-reader cross-checked); fault cells must read back
lossless with retries reported.  The gate: at the 100 ms-RTT no-fault
cell, write-behind must beat the synchronous path by ≥1.5× (theory ~
``parallel_connections``×).

Emits ``BENCH_remote.json`` (repo root by default); field schema in
``benchmarks/README.md``.

Run:  PYTHONPATH=src python benchmarks/bench_remote.py [--quick]
"""

from __future__ import annotations

import argparse
import errno
import gc
import json
import time

from _harness import EVENT_SCHEMA, REPO_ROOT, prebuild
from _legacy_seed_reader import SeedRNTJReader

from repro.core import (  # noqa: E402
    FaultSchedule, FaultSpec, MemorySink, RetryPolicy, RNTJReader,
    SequentialWriter, WriteOptions,
)
from repro.core.remote import (  # noqa: E402
    FakeTransport, ObjectBucket, ObjectStoreSink, RemoteOptions,
)

PAGE = 256 * 1024
CLUSTER = 2 * 1024 * 1024
PART = 1 << 20  # 1 MiB parts: enough parts in flight to expose RTT math

# remote-tuned retry policy, fast backoff so fault cells stay quick
POLICY = RetryPolicy(max_attempts=8, backoff_base=0.0005, backoff_cap=0.01)

MODES = {
    # one connection, no write-behind: commits block on the transport
    "sync": (dict(), RemoteOptions(part_bytes=PART, retry_policy=POLICY,
                                   parallel_connections=1)),
    # emulated-ring write-behind + parallel connections
    "writebehind": (dict(io_inflight_bytes=32 * 1024 * 1024,
                         io_ring="emulated", io_workers=4),
                    RemoteOptions(part_bytes=PART, retry_policy=POLICY,
                                  parallel_connections=4)),
}


def options(**over) -> WriteOptions:
    opts = dict(codec="none", page_size=PAGE, cluster_bytes=CLUSTER,
                precondition=False)
    opts.update(over)
    return WriteOptions(**opts)


def fill_all(writer, batches) -> float:
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        for b in batches:
            writer.fill_batch(b)
        writer.close()
        return time.perf_counter() - t0
    finally:
        gc.enable()


def local_ceiling(batches, cap: int) -> tuple:
    """MemorySink fill+seal: the wall the remote path is chasing."""
    sink = MemorySink(cap)
    w = SequentialWriter(EVENT_SCHEMA, sink, options())
    wall = fill_all(w, batches)
    ref = bytes(sink.buf[: sink.size])
    sink.close()
    return wall, ref


def make_transport(rtt_ms: float, bw_mbps: float, fault_rate: float,
                   bucket=None, seed: int = 0):
    sched = None
    if fault_rate > 0:
        # a scripted floor of two transient part errors guarantees the
        # retry path engages even when the sampled rate over a handful of
        # transport ops happens to draw nothing; the seeded rate adds
        # workload-proportional extras on top
        sched = FaultSchedule(
            [FaultSpec.transient_error(op="part", count=2)],
            seed=seed, error_rate=fault_rate,
            errnos=(errno.EIO, errno.ETIMEDOUT),
            random_ops=("put", "part", "get"))
    return FakeTransport(bucket if bucket is not None else ObjectBucket(),
                         schedule=sched, rtt_s=rtt_ms / 1000.0,
                         bw=bw_mbps * 1e6)


def verify_cell(bucket, ref: bytes, n_entries: int, fault_rate: float,
                label: str) -> None:
    obj = bucket.objects.get("bench.rntj")
    if obj is None:
        raise SystemExit(f"{label}: no object landed")
    if fault_rate == 0 and obj != ref:
        raise SystemExit(f"{label}: object differs from local reference")
    # fault cells: commit contents are identical too (sequential writer),
    # but verify through the readers to exercise the read path
    sink = ObjectStoreSink(make_transport(0, 0, 0, bucket), "bench.rntj",
                           create=False)
    r = RNTJReader(sink)
    ok = r.n_entries == n_entries
    r.close()
    if not ok:
        raise SystemExit(f"{label}: reader sees wrong entry count")


def run_matrix(batches, nbytes: int, n_entries: int, quick: bool,
               out: dict) -> None:
    cells = []
    rtts = [0.0, 20.0, 100.0]
    bws = [0.0, 300.0]          # MB/s; 0 = unlimited
    rates = [0.0, 0.03]
    if quick:
        rtts = [0.0, 100.0]
        bws = [0.0]
    print(f"== remote fill+seal matrix ({len(rtts)}×{len(bws)}×{len(rates)}"
          f" cells × {len(MODES)} modes) ==")
    for rtt in rtts:
        for bw in bws:
            for rate in rates:
                for mode, (engine_kw, ropts) in MODES.items():
                    t = make_transport(rtt, bw, rate)
                    s = ObjectStoreSink(t, "bench.rntj", ropts)
                    w = SequentialWriter(EVENT_SCHEMA, s,
                                         options(retry_policy=POLICY,
                                                 **engine_kw))
                    wall = fill_all(w, batches)
                    d = w.stats.as_dict()
                    label = f"rtt={rtt:g}ms bw={bw:g} rate={rate:g} {mode}"
                    verify_cell(t.bucket, out["_ref"], n_entries, rate,
                                label)
                    rec = {
                        "rtt_ms": rtt, "bw_mbps": bw, "fault_rate": rate,
                        "mode": mode,
                        "wall_s": round(wall, 4),
                        "mb_s": round(nbytes / wall / 1e6, 1),
                        "vs_local": round(out["local_wall_s"] / wall, 3),
                        "retries": d["io_retries"],
                        "degradations": d["io_degradations"],
                        "hedges": d["io_hedges"],
                    }
                    cells.append(rec)
                    print(f"  {label:38s} {rec['mb_s']:8.1f} MB/s "
                          f"({rec['vs_local']:.2f}× local ceiling, "
                          f"{rec['retries']} retries)")
                    if rate > 0 and rec["retries"] == 0 \
                            and rec["degradations"] == 0:
                        raise SystemExit(
                            f"{label}: faults configured but zero retries")
    out["cells"] = cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--entries", type=int, default=None)
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_remote.json"))
    args = ap.parse_args()

    # ~36 B per synthetic event: 16 MiB quick / 24 MiB full — 16 / 24
    # parts, enough that the fixed close-time tail (footer part re-upload
    # + complete round trip) doesn't dominate the pipelining ratio
    entries = args.entries or (440_000 if args.quick else 660_000)
    batches = prebuild("uniform", entries, 20_000)
    nbytes = sum(sum(a.nbytes for a in b.data.values()) for b in batches)
    print(f"workload: {entries} entries, {nbytes / 1e6:.1f} MB uncompressed")

    out = {"entries": entries, "uncompressed_mb": round(nbytes / 1e6, 1),
           "part_bytes": PART, "quick": args.quick}
    local_wall, ref = local_ceiling(batches, int(nbytes * 1.5))
    out["local_wall_s"] = round(local_wall, 4)
    out["local_mb_s"] = round(nbytes / local_wall / 1e6, 1)
    out["_ref"] = ref
    print(f"local ceiling (MemorySink): {out['local_mb_s']} MB/s")

    run_matrix(batches, nbytes, entries, args.quick, out)
    del out["_ref"]

    # seed-reader crosscheck on one clean remote object
    bkt = ObjectBucket()
    bkt.objects["bench.rntj"] = ref
    seed_r = SeedRNTJReader(
        ObjectStoreSink(make_transport(0, 0, 0, bkt), "bench.rntj",
                        create=False))
    if seed_r.n_entries != entries:
        raise SystemExit("seed reader disagrees with the remote object")
    seed_r.close()
    out["seed_reader_ok"] = True

    # gate: at 100 ms RTT (no faults, unlimited bw) write-behind +
    # parallel connections must hold ≥1.5× the synchronous path
    hi = {c["mode"]: c for c in out["cells"]
          if c["rtt_ms"] == 100.0 and c["bw_mbps"] == 0.0
          and c["fault_rate"] == 0.0}
    speedup = hi["sync"]["wall_s"] / hi["writebehind"]["wall_s"]
    out["pipeline_speedup_at_100ms"] = round(speedup, 2)
    out["remote_gate_met"] = speedup >= 1.5
    print(f"  -> write-behind speedup at 100 ms RTT: {speedup:.2f}× "
          f"(gate ≥1.5×): {'PASS' if out['remote_gate_met'] else 'MISS'}")

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    if not out["remote_gate_met"]:
        raise SystemExit("remote pipeline gate missed (see table above)")


if __name__ == "__main__":
    main()
