"""Multi-process shared-file writing benchmark — what the side-car
extent protocol costs and buys.

Measures, on the paper's synthetic nested-event workload:

 1. **N-process scaling** — the same total workload written into ONE
    container file by N forked writer processes through
    ``MultiWriterCoordinator`` / ``join_container`` (DESIGN.md §8.6),
    against a plain single-process ``SequentialWriter`` of the same
    bytes.  Codec zlib level 1, so the work is CPU-bound and extra
    processes can actually pay off; the shared extent log serializes
    only reservation/commit records, never the compression.  Gains are
    bounded by the harness's measured parallel-capacity probe.
 2. **recovery time** — ``recover_container`` over multi-writer files
    that never reached the footer rendezvous: a clean coordinator
    crash (all writers DONE, no seal) and a degraded one (one writer
    killed mid-save, lease left dangling).  Scan MB/s plus the
    side-car replay and fencing attribution on top of it.

Emits ``BENCH_mpwrite.json`` (repo root by default); the field schema
is documented in ``benchmarks/README.md``.

Run:  PYTHONPATH=src python benchmarks/bench_mpwrite.py [--quick]
"""

from __future__ import annotations

import argparse
import gc
import json
import multiprocessing
import os
import tempfile
import time

from _harness import (EVENT_SCHEMA, REPO_ROOT, prebuild,
                      probe_parallel_capacity)

from repro.core import (  # noqa: E402
    MultiWriterCoordinator, RNTJReader, SequentialWriter, WriteOptions,
    join_container, recover_container,
)

PAGE = 256 * 1024
CLUSTER = 4 * 1024 * 1024

# fork children inherit the prebuilt workload copy-on-write; passing the
# batches through a pickle pipe would dwarf the write being measured
_BATCHES = []


def options(codec: str = "zlib", **over) -> WriteOptions:
    opts = dict(codec=codec, level=1, page_size=PAGE, cluster_bytes=CLUSTER,
                buffered=True, journal=True, precondition=False)
    opts.update(over)
    return WriteOptions(**opts)


def _worker(path, idxs, opts, crash_after=None):
    """Forked writer: join the shared container, write its slice.

    ``crash_after`` kills the process (no DONE, dangling lease) after
    that many batches have been flushed — the degraded-recovery cell.
    """
    w = join_container(path, schema=EVENT_SCHEMA, options=opts)
    ctx = w.create_fill_context()
    for n, i in enumerate(idxs, 1):
        ctx.fill_batch(_BATCHES[i])
        if crash_after is not None and n >= crash_after:
            ctx.flush_cluster()
            os._exit(1)
    ctx.close()
    w.close()


def _mp_write(path, n_writers, opts, crash_worker=None, crash_after=None,
              seal=True):
    """One multi-writer run; returns (wall_s, report_or_None, exitcodes).

    The wall clock covers everything a user pays: coordinator setup,
    fork + join of the workers, and the footer rendezvous.
    """
    slices = [list(range(w, len(_BATCHES), n_writers))
              for w in range(n_writers)]
    ctx = multiprocessing.get_context("fork")
    t0 = time.perf_counter()
    coord = MultiWriterCoordinator(EVENT_SCHEMA, path, opts)
    procs = []
    for w, idxs in enumerate(slices):
        ca = crash_after if w == crash_worker else None
        procs.append(ctx.Process(target=_worker,
                                 args=(path, idxs, opts, ca)))
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    report = None
    if seal:
        report = coord.seal(expect_writers=n_writers)
        coord.close()
        wall = time.perf_counter() - t0
    else:
        # coordinator "crashes": no seal, no footer
        wall = time.perf_counter() - t0
        coord.sink.close()
        coord.log.close()
    return wall, report, [p.exitcode for p in procs]


# ---------------------------------------------------------------------------
# 1: N-process scaling


def run_scaling(nbytes: int, entries: int, ns, repeats: int,
                out: dict) -> None:
    print("== N-process scaling (best of %d, zlib level 1) ==" % repeats)
    opts = options()
    out["scaling"] = []
    with tempfile.TemporaryDirectory(prefix="rntj-mpbench-") as tmp:
        # single-process reference: same bytes, same codec, no protocol
        seq_walls = []
        for r in range(repeats):
            path = os.path.join(tmp, f"seq-{r}.rntj")
            gc.collect()
            t0 = time.perf_counter()
            with SequentialWriter(EVENT_SCHEMA, path, opts) as w:
                for b in _BATCHES:
                    w.fill_batch(b)
            seq_walls.append(time.perf_counter() - t0)
            os.unlink(path)
        seq = min(seq_walls)
        out["seq"] = {"wall_s": round(seq, 4),
                      "mb_s": round(nbytes / seq / 1e6, 1)}
        print(f"  seq (SequentialWriter) {out['seq']['mb_s']:8.1f} MB/s")

        for n in ns:
            walls = []
            for r in range(repeats):
                path = os.path.join(tmp, f"mp{n}-{r}.rntj")
                gc.collect()
                wall, report, codes = _mp_write(path, n, opts)
                if any(codes) or report["fenced"] or report["abandoned"]:
                    raise SystemExit(f"clean {n}-writer run degraded: "
                                     f"exit={codes} report={report}")
                if r == 0:  # lossless check once per N, outside timing
                    rd = RNTJReader(path)
                    if rd.n_entries != entries:
                        raise SystemExit(
                            f"{n}-writer file lost entries: "
                            f"{rd.n_entries} != {entries}")
                    rd.close()
                walls.append(wall)
                os.unlink(path)
            best = min(walls)
            rec = {
                "writers": n,
                "wall_s": round(best, 4),
                "mb_s": round(nbytes / best / 1e6, 1),
                "speedup_vs_seq": round(seq / best, 2),
            }
            out["scaling"].append(rec)
            print(f"  {n} writer(s)            {rec['mb_s']:8.1f} MB/s  "
                  f"speedup x{rec['speedup_vs_seq']:.2f}")


# ---------------------------------------------------------------------------
# 2: recovery time on unsealed multi-writer files


def run_recovery(nbytes: int, out: dict) -> None:
    print("== multi-writer recovery time ==")
    # codec none: the scan is pread + crc32, so MB/s reflects the file
    # walk plus the side-car replay, not decompression
    opts = options("none", cluster_bytes=1 << 20, page_size=64 * 1024)
    half = max(1, len(_BATCHES) // 4)
    cases = [("unsealed", None, None),
             ("killed_writer", 1, half)]
    out["recovery"] = []
    for name, crash_worker, crash_after in cases:
        with tempfile.TemporaryDirectory(prefix="rntj-mpbench-") as tmp:
            path = os.path.join(tmp, "mp.rntj")
            _, _, codes = _mp_write(path, 2, opts, crash_worker=crash_worker,
                                    crash_after=crash_after, seal=False)
            if crash_worker is not None and codes[crash_worker] != 1:
                raise SystemExit(f"crash worker exited {codes}")
            fsize = os.path.getsize(path)
            gc.collect()
            t0 = time.perf_counter()
            rep = recover_container(path)
            wall = time.perf_counter() - t0
            if rep.multiwriter is None:
                raise SystemExit("recovery ignored the side-car log")
            rd = RNTJReader(path)
            readable = rd.n_entries
            rd.close()
            rec = {
                "case": name,
                "file_mb": round(fsize / 1e6, 1),
                "wall_s": round(wall, 4),
                "mb_s": round(fsize / wall / 1e6, 1),
                "writers": rep.multiwriter["writers"],
                "clusters_salvaged": rep.clusters_salvaged,
                "clusters_dropped": len(rep.clusters_dropped),
                "entries_readable": readable,
            }
            out["recovery"].append(rec)
            print(f"  {name:14s} {rec['mb_s']:8.1f} MB/s  "
                  f"({rec['file_mb']} MB, {rec['clusters_salvaged']} "
                  f"clusters, {readable} entries readable)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--entries", type=int, default=None)
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_mpwrite.json"))
    args = ap.parse_args()

    entries = args.entries or (400_000 if args.quick else 1_200_000)
    repeats = 3 if args.quick else 5
    global _BATCHES
    _BATCHES = prebuild("uniform", entries, 25_000)
    nbytes = sum(sum(a.nbytes for a in b.data.values()) for b in _BATCHES)
    print(f"workload: {entries} entries, {nbytes / 1e6:.1f} MB uncompressed")

    cap = probe_parallel_capacity()
    out = {"entries": entries, "uncompressed_mb": round(nbytes / 1e6, 1),
           "quick": args.quick, "parallel_capacity": cap}
    print(f"parallel capacity probe: x{cap:.2f}")

    run_scaling(nbytes, entries, (1, 2, 4), repeats, out)
    run_recovery(nbytes, out)

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
