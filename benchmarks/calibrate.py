"""Measure the single-thread cost constants that drive the simulator.

Runs the paper's synthetic benchmark (entries = {id:int64, vals:float32[k]},
k ~ Poisson(5), values uniform [0,100)) through the real writer on this
machine and extracts per-byte seal cost, per-commit critical-section cost,
per-page commit cost and the compression ratio.
"""

from __future__ import annotations

import time
from dataclasses import asdict
from typing import Optional

import numpy as np

from repro.core import (
    ColumnBatch, Collection, DevNullSink, Leaf, ParallelWriter, Schema,
    SequentialWriter, WriteOptions,
)

from .simulate import Costs

EVENT_SCHEMA = Schema([
    Leaf("id", "int64"),
    Collection("vals", Leaf("_0", "float32")),
])


def synth_batch(rng: np.random.Generator, n: int, id0: int = 0) -> ColumnBatch:
    sizes = rng.poisson(5, n).astype(np.int64)
    vals = rng.uniform(0, 100, int(sizes.sum())).astype(np.float32)
    return ColumnBatch.from_arrays(
        EVENT_SCHEMA, n,
        {"id": np.arange(id0, id0 + n), "vals": sizes, "vals._0": vals},
    )


def write_entries_devnull(n_entries: int, options: WriteOptions,
                          batch_entries: int = 100_000, parallel: bool = False):
    """-> (wall_s, stats) writing n_entries of synthetic data to /dev/null."""
    rng = np.random.default_rng(0)
    sink = DevNullSink()
    w = (ParallelWriter if parallel else SequentialWriter)(
        EVENT_SCHEMA, sink, options)
    fill = w.create_fill_context() if parallel else w
    t0 = time.perf_counter()
    done = 0
    while done < n_entries:
        n = min(batch_entries, n_entries - done)
        fill.fill_batch(synth_batch(rng, n, id0=done))
        done += n
    if parallel:
        fill.close()
    w.close()
    return time.perf_counter() - t0, w.stats


def calibrate(n_entries: int = 500_000, codec: str = "zlib",
              cluster_bytes: int = 8 << 20) -> Costs:
    opts = WriteOptions(codec=codec, level=1, cluster_bytes=cluster_bytes)
    wall, stats = write_entries_devnull(n_entries, opts)
    seal_s = stats.seal_ns / 1e9
    # the critical section = lock-held time (reserve + metadata + write)
    commit_s = stats.lock.held_ns / 1e9 / max(stats.clusters, 1)
    # unbuffered: per-page critical section
    opts_u = WriteOptions(codec=codec, level=1, cluster_bytes=cluster_bytes,
                          buffered=False)
    wall_u, stats_u = write_entries_devnull(n_entries, opts_u, parallel=True)
    page_commit_s = (stats_u.lock.held_ns / 1e9) / max(stats_u.pages, 1)
    return Costs(
        seal_s_per_byte=seal_s / max(stats.uncompressed_bytes, 1),
        commit_s=commit_s,
        page_commit_s=page_commit_s,
        compression_ratio=stats.compressed_bytes / max(stats.uncompressed_bytes, 1),
        cluster_bytes=cluster_bytes,
        pages_per_cluster=max(1, round(stats.pages / max(stats.clusters, 1))),
    )


if __name__ == "__main__":
    c = calibrate()
    for k, v in asdict(c).items():
        print(f"{k}: {v}")
