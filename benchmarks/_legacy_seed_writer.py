"""The pre-ISSUE-1 write hot path, vendored verbatim for benchmarking.

This module preserves the seed's fill→seal→commit implementation (commit
e3e94c7) so ``bench_writer.py`` can measure the rebuilt engine against the
*actual* pre-PR code path rather than a reconstruction:

* per-column Python **lists of chunk arrays**, ``np.concatenate`` at seal,
* per-page ``precondition`` returning fresh ``bytes``
  (``tobytes``/``planes.T.tobytes()``/3-temporary delta-zigzag-split),
* strictly serial page compression inside ``seal()``,
* ``b"".join`` blob assembly,
* the same commit critical section (reserve + metadata + pwrite).

Do not optimize this file — it is a measurement baseline, not product code.
"""

from __future__ import annotations

import time
import zlib
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import compression as comp
from repro.core.cluster import SealedCluster
from repro.core.container import Sink
from repro.core.encoding import dzs_encode, split_encode
from repro.core.metadata import ClusterMeta
from repro.core.pages import PageDesc, elements_per_page
from repro.core.schema import (
    ENC_DELTA_ZIGZAG_SPLIT, ENC_NONE, ENC_SPLIT, KIND_OFFSET, OFFSET_DTYPE,
    ColumnBatch, ColumnSpec, Schema,
)
from repro.core.stats import CountingLock, WriterStats


# -- seed encoding.precondition ---------------------------------------------

def _seed_precondition(arr: np.ndarray, encoding: str) -> bytes:
    if encoding == ENC_NONE:
        return np.ascontiguousarray(arr).tobytes()
    if encoding == ENC_SPLIT:
        return split_encode(arr)
    if encoding == ENC_DELTA_ZIGZAG_SPLIT:
        return dzs_encode(arr)
    raise ValueError(f"unknown encoding {encoding!r}")


# -- seed compression.compress (frozen: one-shot zlib.compress) --------------

def _seed_compress(data: bytes, codec: int, level: int) -> bytes:
    if codec == comp.CODEC_NONE:
        return data
    if level < 0:
        level = comp.DEFAULT_LEVEL[codec]
    if codec == comp.CODEC_ZLIB:
        return zlib.compress(data, level)
    return comp.compress(data, codec, level)


# -- seed pages.build_page ---------------------------------------------------

def _seed_build_page(col: ColumnSpec, elements: np.ndarray, codec: int,
                     level: int = -1, checksum: bool = True):
    raw = _seed_precondition(elements, col.encoding)
    payload = _seed_compress(raw, codec, level)
    used_codec = codec
    if len(payload) >= len(raw):
        payload, used_codec = raw, comp.CODEC_NONE
    crc = zlib.crc32(payload) if checksum else 0
    desc = PageDesc(
        column=col.index,
        n_elements=int(len(elements)),
        offset=-1,
        size=len(payload),
        uncompressed_size=len(raw),
        checksum=crc,
        codec=used_codec,
    )
    return payload, desc


# -- seed cluster.ClusterBuilder ---------------------------------------------

class SeedClusterBuilder:
    def __init__(self, schema: Schema, page_size: int, codec: int,
                 level: int = -1, checksum: bool = True):
        self.schema = schema
        self.page_size = page_size
        self.codec = codec
        self.level = level
        self.checksum = checksum
        self._chunks: List[List[np.ndarray]] = [[] for _ in schema.columns]
        self._acc_offset = [0] * schema.n_columns
        self._n_elements = [0] * schema.n_columns
        self.n_entries = 0
        self.uncompressed_bytes = 0
        self._page_elems = [
            elements_per_page(c, page_size) for c in schema.columns
        ]

    def fill_batch(self, batch: ColumnBatch) -> None:
        arrays = [batch.data[c.index] for c in self.schema.columns]
        self._append_arrays(arrays, batch.n_entries)

    def _append_arrays(self, arrays: Sequence[np.ndarray], n_entries: int) -> None:
        for col in self.schema.columns:
            a = arrays[col.index]
            if col.kind == KIND_OFFSET:
                offs = np.cumsum(a.astype(np.int64, copy=False), dtype=np.int64) \
                    + self._acc_offset[col.index]
                if len(offs):
                    self._acc_offset[col.index] = int(offs[-1])
                a = offs
            if len(a):
                self._chunks[col.index].append(a)
                self._n_elements[col.index] += len(a)
                self.uncompressed_bytes += a.nbytes
        self.n_entries += n_entries

    @property
    def is_empty(self) -> bool:
        return self.n_entries == 0

    def _column_elements(self, idx: int) -> np.ndarray:
        chunks = self._chunks[idx]
        if not chunks:
            col = self.schema.columns[idx]
            dt = OFFSET_DTYPE if col.kind == KIND_OFFSET else col.dtype
            return np.empty(0, dtype=dt)
        if len(chunks) == 1:
            return chunks[0]
        return np.concatenate(chunks)

    def seal(self) -> SealedCluster:
        t0 = time.perf_counter_ns()
        parts: List[bytes] = []
        descs: List[PageDesc] = []
        pos = 0
        for col in self.schema.columns:
            elems = self._column_elements(col.index)
            per = self._page_elems[col.index]
            for start in range(0, len(elems), per):
                payload, desc = _seed_build_page(
                    col, elems[start : start + per], self.codec, self.level,
                    self.checksum,
                )
                desc.offset = pos
                pos += desc.size
                parts.append(payload)
                descs.append(desc)
        sealed = SealedCluster(
            blob=b"".join(parts),
            n_entries=self.n_entries,
            n_elements=list(self._n_elements),
            pages=descs,
            uncompressed_bytes=self.uncompressed_bytes,
            seal_ns=time.perf_counter_ns() - t0,
        )
        self._chunks = [[] for _ in self.schema.columns]
        self._acc_offset = [0] * self.schema.n_columns
        self._n_elements = [0] * self.schema.n_columns
        self.n_entries = 0
        self.uncompressed_bytes = 0
        return sealed


# -- seed writer commit loop (metadata kept in memory; no finalization —
#    the benchmark measures fill+seal+commit, not footer writing) ------------

class SeedSequentialWriter:
    def __init__(self, schema: Schema, sink: Sink, *, page_size: int,
                 codec: int, level: int, cluster_bytes: int,
                 checksum: bool = True):
        self.schema = schema
        self.sink = sink
        self.cluster_bytes = cluster_bytes
        self.lock = CountingLock()
        self.stats = WriterStats()
        self._clusters: List[ClusterMeta] = []
        self._n_entries = 0
        self._builder = SeedClusterBuilder(schema, page_size, codec, level,
                                           checksum)

    def fill_batch(self, batch: ColumnBatch) -> None:
        self._builder.fill_batch(batch)
        if self._builder.uncompressed_bytes >= self.cluster_bytes:
            self.flush_cluster()

    def flush_cluster(self) -> None:
        if self._builder.is_empty:
            return
        sealed = self._builder.seal()
        t0 = time.perf_counter_ns()
        with self.lock:
            off = self.sink.reserve(sealed.size)
            first_entry = self._n_entries
            self._n_entries += sealed.n_entries
            self._clusters.append(
                ClusterMeta(
                    first_entry=first_entry,
                    n_entries=sealed.n_entries,
                    n_elements=sealed.n_elements,
                    pages=sealed.rebase(off),
                    byte_offset=off,
                    byte_size=sealed.size,
                )
            )
            self.sink.pwrite(off, sealed.blob)
        self.stats.commit_ns += time.perf_counter_ns() - t0
        self.stats.seal_ns += sealed.seal_ns
        self.stats.clusters += 1
        self.stats.pages += len(sealed.pages)
        self.stats.entries += sealed.n_entries
        self.stats.uncompressed_bytes += sealed.uncompressed_bytes
        self.stats.compressed_bytes += sealed.size

    def close(self) -> None:
        self.flush_cluster()
