"""Fault-tolerance benchmark — what crash consistency and retries cost.

Measures, on the paper's synthetic nested-event workload:

 1. **journal overhead** — the v2 per-cluster envelope + commit-journal
    framing (DESIGN.md §8.3) against the same write with ``journal=False``,
    at codec ``none`` (commit path fully exposed) and ``zlib`` (realistic
    CPU mix), on DevNull and Memory sinks.  Configs are interleaved per
    round and overhead is the *median of per-round paired ratios*, so
    container drift and outlier rounds cancel out.  Target: <2%
    wall-time overhead — the framing is ~100 bytes per multi-megabyte
    cluster and is serialized outside the writer's critical section.
 2. **retry-path overhead** — the same write with an engaged
    :class:`RetryPolicy`: what the retry chokepoint costs when nothing
    ever fails.
 3. **recovery throughput** — ``recover_container`` over a torn copy
    (truncated mid-cluster) of a large many-cluster file — 1 GiB, or
    64 MiB under ``--quick``: scan + page-CRC verification MB/s, with
    and without ``verify_pages``.

Emits ``BENCH_fault.json`` (repo root by default); the field schema is
documented in ``benchmarks/README.md``.

Run:  PYTHONPATH=src python benchmarks/bench_fault.py [--quick]
"""

from __future__ import annotations

import argparse
import gc
import json
import time
from typing import Dict

from _harness import EVENT_SCHEMA, REPO_ROOT, prebuild

from repro.core import (  # noqa: E402
    DevNullSink, MemorySink, RetryPolicy, RNTJReader, SequentialWriter,
    WriteOptions, recover_container,
)
from repro.core.faults import memory_sink_from_bytes  # noqa: E402

PAGE = 256 * 1024
CLUSTER = 2 * 1024 * 1024


def options(codec: str, **over) -> WriteOptions:
    opts = dict(codec=codec, level=1, page_size=PAGE, cluster_bytes=CLUSTER,
                precondition=False)
    opts.update(over)
    return WriteOptions(**opts)


def fill_all(writer, batches) -> float:
    gc.collect()
    gc.disable()
    try:
        t0 = time.perf_counter()
        for b in batches:
            writer.fill_batch(b)
        writer.close()
        return time.perf_counter() - t0
    finally:
        gc.enable()


def run_interleaved(sink_factory, batches, configs: Dict[str, WriteOptions],
                    repeats: int) -> Dict[str, list]:
    """Per-config wall time for every round, configs interleaved within a
    round so each round is a *paired* sample (drift hits all configs in
    the round roughly equally)."""
    walls = {name: [] for name in configs}
    for _ in range(repeats):
        for name, opts in configs.items():
            w = SequentialWriter(EVENT_SCHEMA, sink_factory(), opts)
            walls[name].append(fill_all(w, batches))
    return walls


def paired_overhead_pct(walls: list, base: list) -> float:
    """Median of the per-round wall ratios — each round's configs ran
    back-to-back, so their ratio cancels box drift; the median across
    rounds shrugs off individual outlier rounds, where a best-of-N
    ratio inherits whichever config got the single luckiest run."""
    ratios = sorted(w / b for w, b in zip(walls, base))
    mid = len(ratios) // 2
    med = (ratios[mid] if len(ratios) % 2
           else (ratios[mid - 1] + ratios[mid]) / 2.0)
    return (med - 1.0) * 100.0


# ---------------------------------------------------------------------------
# 1 + 2: journal framing and retry chokepoint overhead


def run_overhead(batches, nbytes: int, repeats: int, out: dict) -> None:
    print("== journal + retry overhead "
          "(median paired ratio over %d rounds) ==" % repeats)
    out["overhead"] = []
    policy = RetryPolicy()
    # codec none commits at GB/s, so the base workload's wall is a few ms
    # and per-run setup would drown a 2% effect — feed it the same
    # prebuilt batches several times over so every cell runs >100 ms
    workloads = {"none": batches * 16, "zlib": batches}
    for codec in ("none", "zlib"):
        work = workloads[codec]
        wbytes = nbytes * (len(work) // len(batches))
        # preallocated memory sink: measure framing, not bytearray realloc
        cap = int(wbytes * 1.25)
        sinks = (("devnull", DevNullSink),
                 ("memory", lambda: MemorySink(cap)))
        for sink_name, factory in sinks:
            # "baseline2" repeats the no-journal config verbatim: its
            # delta vs "nojournal" is this cell's same-config noise floor,
            # and a journal overhead is only a real miss when it exceeds
            # the target by more than that floor.  The ring trio measures
            # the same journal delta on BENCH_io's scatter+ring
            # write-behind configuration — with its own baseline2, since
            # write-behind walls (producer + worker thread on a small box)
            # are noisier than the synchronous path's.
            ring = dict(io_inflight_bytes=32 * 1024 * 1024,
                        io_ring="emulated", io_workers=1)
            configs = {
                "nojournal": options(codec, journal=False),
                "baseline2": options(codec, journal=False),
                "journal": options(codec),
                "journal+retry": options(codec, retry_policy=policy),
                "ring-nojournal": options(codec, journal=False, **ring),
                "ring-baseline2": options(codec, journal=False, **ring),
                "ring-journal": options(codec, **ring),
            }
            walls = run_interleaved(factory, work, configs, repeats)
            for mode, series in walls.items():
                base = walls["ring-nojournal" if mode.startswith("ring")
                             else "nojournal"]
                pct = paired_overhead_pct(series, base)
                best = min(series)
                rec = {
                    "codec": codec,
                    "sink": sink_name,
                    "mode": mode,
                    "wall_s": round(best, 4),
                    "mb_s": round(wbytes / best / 1e6, 1),
                    "overhead_pct": round(pct, 2),
                }
                out["overhead"].append(rec)
                print(f"  {codec:5s} {sink_name:7s} {mode:14s} "
                      f"{rec['mb_s']:8.1f} MB/s  overhead "
                      f"{rec['overhead_pct']:+6.2f}%")

    worst = max(r["overhead_pct"] for r in out["overhead"]
                if r["mode"] in ("journal", "ring-journal"))
    noise = max(abs(r["overhead_pct"]) for r in out["overhead"]
                if r["mode"] in ("baseline2", "ring-baseline2"))
    out["journal_overhead_worst_pct"] = round(worst, 2)
    out["noise_floor_pct"] = round(noise, 2)
    met = worst < 2.0 + noise
    out["journal_overhead_target_met"] = met
    print(f"  -> worst journal overhead {worst:+.2f}% "
          f"(target <2%, same-config noise floor ±{noise:.2f}%): "
          f"{'PASS' if met else 'MISS'}")


# ---------------------------------------------------------------------------
# 3: recovery throughput


def run_recovery(target_mb: int, out: dict) -> None:
    print(f"== recovery throughput (~{target_mb} MB torn file) ==")
    # ~36 B per synthetic event; 1 MiB clusters so the file holds many
    # independently salvageable clusters (recovery granularity)
    entries = target_mb * 1_000_000 // 36
    batches = prebuild("uniform", entries, 100_000)
    sink = MemorySink(int(target_mb * 1.25e6))
    w = SequentialWriter(EVENT_SCHEMA, sink, options(
        "none", cluster_bytes=1 << 20, page_size=64 * 1024))
    fill_all(w, batches)
    del batches
    # cut mid-way through the final cluster: the scan walks every intact
    # cluster and has to detect + drop the torn tail
    cut = int(sink.size * 0.995)
    data = bytes(sink.buf[:cut])
    del sink
    out["recovery"] = []
    for verify in (True, False):
        ms = memory_sink_from_bytes(data, slack=16 * 1024 * 1024)
        t0 = time.perf_counter()
        rep = recover_container(ms, verify_pages=verify)
        wall = time.perf_counter() - t0
        r = RNTJReader(ms)
        entries = r.n_entries
        r.close()
        if not (rep.clusters_salvaged > 0
                and entries == rep.entries_salvaged):
            raise SystemExit(
                f"recovery broken: salvaged {rep.clusters_salvaged} "
                f"clusters / {rep.entries_salvaged} entries, reader sees "
                f"{entries}")
        rec = {
            "file_mb": round(cut / 1e6, 1),
            "verify_pages": verify,
            "wall_s": round(wall, 4),
            "mb_s": round(cut / wall / 1e6, 1),
            "clusters_salvaged": rep.clusters_salvaged,
            "entries_salvaged": rep.entries_salvaged,
            "entries_readable": entries,
            "resyncs": rep.resyncs,
        }
        out["recovery"].append(rec)
        print(f"  verify={str(verify):5s} {rec['mb_s']:8.1f} MB/s  "
              f"({rec['file_mb']} MB, {rep.clusters_salvaged} clusters, "
              f"{rep.entries_salvaged} entries)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--entries", type=int, default=None)
    ap.add_argument("--out", default=str(REPO_ROOT / "BENCH_fault.json"))
    args = ap.parse_args()

    entries = args.entries or (120_000 if args.quick else 400_000)
    repeats = 6 if args.quick else 9
    batches = prebuild("uniform", entries, 20_000)
    nbytes = sum(sum(a.nbytes for a in b.data.values()) for b in batches)
    print(f"workload: {entries} entries, {nbytes / 1e6:.1f} MB uncompressed")

    out = {"entries": entries, "uncompressed_mb": round(nbytes / 1e6, 1),
           "quick": args.quick}
    run_overhead(batches, nbytes, repeats, out)
    del batches

    # recovery scans a much bigger file than the overhead matrix writes:
    # the scan is sequential pread + crc32, so file size is what matters
    run_recovery(64 if args.quick else 1024, out)

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    if not out["journal_overhead_target_met"]:
        raise SystemExit("journal overhead gate missed (see table above)")


if __name__ == "__main__":
    main()
