"""Paper Fig. 3: synthetic benchmark against an SSD bandwidth limit.

The paper measures fio limits on its Samsung PM1733: 771 MB/s (growing
file) and 1075 MB/s (fallocate-preallocated), then shows parallel writing
reaching ~91% / ~88% of those limits uncompressed, and a compressed
plateau (576 / 729 MB/s) once compression outpaces the device.

Here: 1) a real ThrottledSink run validates the device model end-to-end
on this container (a 30 MB/s simulated device must bottleneck the real
writer at ~30 MB/s); 2) the calibrated 64-core simulation sweeps threads
against the paper's device numbers.

Run:  PYTHONPATH=src:. python -m benchmarks.fig3_ssd
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core import DevNullSink, ParallelWriter, ThrottledSink, WriteOptions

from .calibrate import EVENT_SCHEMA, calibrate, synth_batch
from .simulate import Costs, Device, simulate

RESULTS = Path(__file__).parent / "results"

SSD_BW = 771e6
SSD_BW_PREALLOC = 1075e6


def validate_device_model(bw_mb: float = 30.0, entries: int = 150_000) -> dict:
    """Real writer against a throttled sink: measured == modeled plateau."""
    sink = ThrottledSink(DevNullSink(), bw=bw_mb * 1e6)
    w = ParallelWriter(EVENT_SCHEMA, sink,
                       WriteOptions(codec="none"))
    rng = np.random.default_rng(0)
    ctx = w.create_fill_context()
    t0 = time.perf_counter()
    done = 0
    while done < entries:
        n = min(50_000, entries - done)
        ctx.fill_batch(synth_batch(rng, n, id0=done))
        done += n
    ctx.close()
    w.close()
    wall = time.perf_counter() - t0
    mbs = w.stats.compressed_bytes / wall / 1e6
    return {"device_mb_s": bw_mb, "measured_mb_s": round(mbs, 1),
            "ratio": round(mbs / bw_mb, 3)}


def run(full: bool = True) -> dict:
    out = {"validation": validate_device_model(), "projected": []}
    v = out["validation"]
    print(f"device-model validation: {v['measured_mb_s']} MB/s on a "
          f"{v['device_mb_s']} MB/s device (ratio {v['ratio']})")

    costs = calibrate(200_000)
    uncomp = Costs(**{**costs.__dict__, "compression_ratio": 1.0,
                      "seal_s_per_byte": costs.seal_s_per_byte * 0.12})
    device = Device(bw=SSD_BW, bw_prealloc=SSD_BW_PREALLOC)
    sims = {
        "zlib-buffered": dict(costs=costs, buffered=True),
        "zlib-unbuffered": dict(costs=costs, buffered=False),
        "uncompressed": dict(costs=uncomp, buffered=True),
        "uncompressed+fallocate": dict(costs=uncomp, buffered=True,
                                       fallocate=True),
    }
    threads = [1, 2, 4, 8, 16, 32, 64, 128] if full else [1, 64]
    print(f"{'config':24s} " + " ".join(f"{t:>7d}" for t in threads))
    for name, kw in sims.items():
        row = []
        for n in threads:
            r = simulate(n, 24, device=device, n_cores=64, **kw)
            row.append(r.bandwidth_compressed / 1e6)
            out["projected"].append({
                "config": name, "threads": n,
                "mb_s": r.bandwidth_compressed / 1e6,
                "device_busy_frac": r.device_busy_s / r.wall_s,
            })
        print(f"{name:24s} " + " ".join(f"{x:7.0f}" for x in row))

    # paper comparison points
    unc = [p for p in out["projected"] if p["config"] == "uncompressed"]
    peak = max(p["mb_s"] for p in unc)
    out["peak_fraction_of_limit"] = peak / (SSD_BW / 1e6)
    print(f"uncompressed peak = {peak:.0f} MB/s = "
          f"{out['peak_fraction_of_limit']:.0%} of the 771 MB/s limit "
          f"(paper: 91%)")
    falloc = [p for p in out["projected"]
              if p["config"] == "uncompressed+fallocate"]
    peak_f = max(p["mb_s"] for p in falloc)
    out["peak_fraction_of_prealloc_limit"] = peak_f / (SSD_BW_PREALLOC / 1e6)
    print(f"fallocate peak     = {peak_f:.0f} MB/s = "
          f"{out['peak_fraction_of_prealloc_limit']:.0%} of 1075 MB/s "
          f"(paper: 88%)")

    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "fig3_ssd.json").write_text(json.dumps(out, indent=1))
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
