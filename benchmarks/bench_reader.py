"""Read-path throughput benchmark — the read-side trajectory for this repo.

Measures, on the paper's synthetic nested-event workload
(``{id: int64, vals: float32[k]}, k ~ Poisson(5)``):

 1. **cluster-read** throughput of the rebuilt read engine (coalesced
    preads + pooled page decode + prefetch pipeline) against the
    **actual pre-refactor code path** (vendored verbatim in
    ``_legacy_seed_reader.py``: one pread per page, serial per-page
    decode, ``np.concatenate`` per column), for codec none and zlib and
    1/2/4 decode workers.
 2. the **end-to-end fig5 skim delta**: the paper's §6.2 skimming
    application driven by the seed reader vs the read engine.  The skim
    outputs must have identical ``kept_events`` and **byte-identical**
    output files — the refactor may only change *when* bytes are read,
    never what is written.

Emits ``BENCH_reader.json`` (repo root by default).  Scratch files live
in ``benchmarks/_scratch_reader/`` (gitignored) and are removed on exit.

Run:  PYTHONPATH=src python benchmarks/bench_reader.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import time
from pathlib import Path
from typing import Dict, List

from _harness import (  # noqa: F401
    EVENT_SCHEMA, REPO_ROOT, build_file, probe_parallel_capacity,
)

from repro.core import (  # noqa: E402
    RNTJReader, ReadOptions, SequentialWriter, WriteOptions,
)
from repro.skim import make_agc_dataset, skim_partitions  # noqa: E402
from repro.skim.engine import (  # noqa: E402
    Cuts, OUT_SCHEMA, _skim_cluster_arrays,
)

from _legacy_seed_reader import SeedRNTJReader  # noqa: E402

SCRATCH = REPO_ROOT / "benchmarks" / "_scratch_reader"


# ---------------------------------------------------------------------------
# 1. cluster-read throughput


def bench_seed_read(path: Path, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        r = SeedRNTJReader(str(path))
        t0 = time.perf_counter()
        for ci in range(r.n_clusters):
            r.read_cluster(ci)
        best = min(best, time.perf_counter() - t0)
        r.close()
    return best


def bench_new_read(path: Path, ropts: ReadOptions, repeats: int):
    best, phases = float("inf"), None
    for _ in range(repeats):
        r = RNTJReader(str(path), options=ropts)
        t0 = time.perf_counter()
        for _ci, _cols in r.iter_clusters():
            pass
        wall = time.perf_counter() - t0
        if wall < best:
            best = wall
            phases = {k: round(v, 1) for k, v in r.stats.phases_ms().items()}
            phases["coalesced_reads"] = r.stats.coalesced_reads
            phases["pages"] = r.stats.pages
        r.close()
    return best, phases


def run_cluster_read(entries: int, repeats: int, out: dict) -> None:
    print("== cluster-read throughput: seed per-page reader vs read engine ==")
    out["cluster_read"] = {}
    for codec, level in [("none", -1), ("zlib", 1)]:
        path = SCRATCH / f"events_{codec}.rntj"
        nbytes = build_file(path, entries, codec, level)
        seed_wall = bench_seed_read(path, repeats)
        rec: dict = {
            "uncompressed_mb": round(nbytes / 1e6, 1),
            "file_mb": round(os.path.getsize(path) / 1e6, 1),
            "seed": {"wall_s": round(seed_wall, 4),
                     "mb_s": round(nbytes / seed_wall / 1e6, 1)},
        }
        configs = [
            ("coalesce_only", ReadOptions(decode_workers=0,
                                          prefetch_clusters=0)),
            ("workers1", ReadOptions(decode_workers=1, prefetch_clusters=0)),
            ("workers2", ReadOptions(decode_workers=2, prefetch_clusters=0)),
            ("workers4", ReadOptions(decode_workers=4, prefetch_clusters=0)),
            ("pipeline", ReadOptions(decode_workers=2, prefetch_clusters=1)),
        ]
        best_wall = float("inf")
        for name, ropts in configs:
            wall, phases = bench_new_read(path, ropts, repeats)
            best_wall = min(best_wall, wall)
            rec[name] = {"wall_s": round(wall, 4),
                         "mb_s": round(nbytes / wall / 1e6, 1),
                         "phases": phases}
            print(f"  {codec:5s} {name:14s} {nbytes / wall / 1e6:8.1f} MB/s "
                  f"(seed {nbytes / seed_wall / 1e6:8.1f} MB/s)")
        rec["speedup_vs_seed"] = round(seed_wall / best_wall, 3)
        out["cluster_read"][codec] = rec
        print(f"  {codec:5s} best speedup vs seed reader: "
              f"{rec['speedup_vs_seed']:.2f}x")
    out["speedup_vs_seed_none"] = out["cluster_read"]["none"]["speedup_vs_seed"]
    out["speedup_vs_seed_zlib"] = out["cluster_read"]["zlib"]["speedup_vs_seed"]


# ---------------------------------------------------------------------------
# 2. end-to-end fig5 skim delta (seed reader vs read engine)


def legacy_imt_skim(parts: Dict[int, List[str]], out_dir: Path,
                    cuts: Cuts) -> int:
    """The fig5 'imt' strategy at 1 thread, driven by the seed reader —
    byte-for-byte the same write path as skim_partitions(strategy='imt',
    n_threads=1), only the read side differs."""
    opts = WriteOptions(codec="zlib", level=1, cluster_bytes=2 * 1024 * 1024,
                        imt_workers=1)
    out_dir.mkdir(parents=True, exist_ok=True)
    kept = 0
    for part, files in parts.items():
        w = SequentialWriter(OUT_SCHEMA, str(out_dir / f"skim_{part}.rntj"),
                             opts)
        try:
            for f in files:
                r = SeedRNTJReader(f)
                try:
                    for ci in range(r.n_clusters):
                        batch = _skim_cluster_arrays(
                            r.schema, r.read_cluster(ci),
                            r.clusters[ci].n_entries, cuts)
                        if batch is not None:
                            w.fill_batch(batch)
                            kept += batch.n_entries
                finally:
                    r.close()
        finally:
            w.close()
    return kept


def run_fig5_delta(events_per_file: int, repeats: int, out: dict) -> None:
    print("== fig5 skim: seed reader vs read engine (must be byte-identical) ==")
    cuts = Cuts()
    parts = make_agc_dataset(str(SCRATCH / "agc"), n_partitions=3,
                             files_per_partition=2,
                             events_per_file=events_per_file, seed=0)

    legacy_dir = SCRATCH / "skim_legacy"
    new_dir = SCRATCH / "skim_new"
    legacy_wall, kept_legacy = float("inf"), None
    for _ in range(repeats):
        shutil.rmtree(legacy_dir, ignore_errors=True)
        t0 = time.perf_counter()
        kept_legacy = legacy_imt_skim(parts, legacy_dir, cuts)
        legacy_wall = min(legacy_wall, time.perf_counter() - t0)

    new_wall, kept_new = float("inf"), None
    # the skim default: prefetch overlap, no decode pool (this container
    # has ~1 effective core — the per-config section quantifies that)
    ropts = ReadOptions(prefetch_clusters=1, decode_workers=0)
    for _ in range(repeats):
        shutil.rmtree(new_dir, ignore_errors=True)
        t0 = time.perf_counter()
        res = skim_partitions(parts, str(new_dir), "imt", n_threads=1,
                              cuts=cuts, read_options=ropts)
        new_wall = min(new_wall, time.perf_counter() - t0)
        kept_new = res["kept_events"]

    identical = all(
        (legacy_dir / f"skim_{p}.rntj").read_bytes()
        == (new_dir / f"skim_{p}.rntj").read_bytes()
        for p in parts
    )
    # cross-strategy agreement through the read engine
    res_par = skim_partitions(parts, str(SCRATCH / "skim_par"), "parallel",
                              n_threads=4, cuts=cuts, read_options=ropts)
    out["fig5_skim"] = {
        "events_per_file": events_per_file,
        "kept_seed_reader": kept_legacy,
        "kept_read_engine": kept_new,
        "kept_parallel_strategy": res_par["kept_events"],
        "outputs_byte_identical": identical,
        "seed_reader_wall_s": round(legacy_wall, 3),
        "read_engine_wall_s": round(new_wall, 3),
        "skim_speedup": round(legacy_wall / new_wall, 3),
    }
    print(f"  kept: seed={kept_legacy} engine={kept_new} "
          f"parallel={res_par['kept_events']}  byte-identical={identical}")
    print(f"  wall: seed {legacy_wall:.2f}s -> engine {new_wall:.2f}s "
          f"({legacy_wall / new_wall:.2f}x)")
    if kept_legacy != kept_new or not identical:
        raise SystemExit("fig5 skim outputs diverged between readers")


def run(entries: int, events_per_file: int, quick: bool, out_path: Path) -> dict:
    SCRATCH.mkdir(parents=True, exist_ok=True)
    repeats = 2 if quick else 4
    out: dict = {
        "benchmark": "bench_reader",
        "schema": "event{id:int64, vals:float32[k~Poisson(5)]}",
        "entries": entries,
        "cpu_count": os.cpu_count(),
        # decode-pool / prefetch gains are bounded by this (shared CI
        # containers often expose ~1 effective core)
        "parallel_capacity_2t": probe_parallel_capacity(),
    }
    print(f"parallel capacity probe (2-thread zlib scaling): "
          f"{out['parallel_capacity_2t']}x of ideal 2.0")
    try:
        run_cluster_read(entries, repeats, out)
        run_fig5_delta(events_per_file, max(1, repeats // 2), out)
    finally:
        shutil.rmtree(SCRATCH, ignore_errors=True)
    out_path.write_text(json.dumps(out, indent=1))
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--entries", type=int, default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small workload for CI smoke runs")
    ap.add_argument("--out", type=str,
                    default=str(REPO_ROOT / "BENCH_reader.json"))
    args = ap.parse_args()
    entries = args.entries or (60_000 if args.quick else 400_000)
    events_per_file = 2_000 if args.quick else 8_000
    run(entries, events_per_file, args.quick, Path(args.out))


if __name__ == "__main__":
    main()
