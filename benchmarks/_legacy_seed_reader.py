"""The seed reader's hot path, vendored verbatim for benchmarking.

This is the pre-ISSUE-2 read path as it stood before the read-engine
rebuild: one ``pread`` per page, serial per-page decompress+decode
(``read_page`` allocates per page), ``np.concatenate`` per column, no
coalescing, no decode pool, no prefetch.  ``bench_reader.py`` measures
the rebuilt engine against exactly this code.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.container import FileSink, Sink
from repro.core.metadata import (
    ANCHOR_SIZE,
    ClusterMeta,
    parse_anchor,
    parse_footer,
    parse_header,
    parse_pagelist,
)
from repro.core.pages import read_page


class SeedRNTJReader:
    def __init__(self, sink_or_path, verify_checksums: bool = True):
        if isinstance(sink_or_path, str):
            self.sink: Sink = FileSink(sink_or_path, create=False)
        else:
            self.sink = sink_or_path
        if not self.sink.readable():
            raise IOError("sink is not readable")
        self.verify = verify_checksums
        size = self.sink.size
        anchor = parse_anchor(self.sink.pread(size - ANCHOR_SIZE, ANCHOR_SIZE))
        hoff, hsize = anchor["header"]
        foff, fsize = anchor["footer"]
        self.schema, self.options = parse_header(self.sink.pread(hoff, hsize))
        footer = parse_footer(self.sink.pread(foff, fsize))
        pl_off, pl_size = footer["pagelist"]
        self.clusters: List[ClusterMeta] = parse_pagelist(
            self.sink.pread(pl_off, pl_size)
        )
        self.n_entries = int(footer["n_entries"])

    @property
    def n_clusters(self) -> int:
        return len(self.clusters)

    def read_cluster(
        self, cluster_index: int, columns: Optional[Sequence[int]] = None
    ) -> Dict[int, np.ndarray]:
        cm = self.clusters[cluster_index]
        want = set(columns) if columns is not None else None
        parts: Dict[int, List[np.ndarray]] = {}
        for desc in cm.pages:
            if want is not None and desc.column not in want:
                continue
            col = self.schema.columns[desc.column]
            buf = self.sink.pread(desc.offset, desc.size)
            parts.setdefault(desc.column, []).append(
                read_page(buf, desc, col, self.verify)
            )
        out: Dict[int, np.ndarray] = {}
        targets = want if want is not None else range(self.schema.n_columns)
        for ci in targets:
            col = self.schema.columns[ci]
            chunks = parts.get(ci, [])
            if chunks:
                out[ci] = np.concatenate(chunks) if len(chunks) > 1 else chunks[0]
            else:
                out[ci] = np.empty(0, dtype=col.dtype)
        return out

    def close(self) -> None:
        self.sink.close()
