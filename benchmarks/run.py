"""Benchmark harness entry point: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows summarizing each benchmark
(us_per_call = microseconds per relevant unit; derived = the headline
metric compared against the paper).

  fig2  weak scaling to /dev/null       (paper Fig. 2)
  fig3  SSD device limits               (paper Fig. 3)
  fig4  HDD device limits               (paper Fig. 4)
  fig5  AGC skimming strategies         (paper Fig. 5)
  roofline  dry-run summary             (EXPERIMENTS §Roofline; requires
            benchmarks/results/dryrun/*.json from repro.launch.dryrun)

``--list`` enumerates every runnable benchmark (the figure harnesses
above plus the per-engine ``bench_*.py`` scripts and the JSON each one
emits — the same names benchmarks/README.md documents) and exits.

Run:  PYTHONPATH=src python -m benchmarks.run [--quick | --list]
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import fig2_devnull, fig3_ssd, fig4_hdd, fig5_skim, roofline

# every runnable benchmark: (name, invocation, emitted artifact).
# benchmarks/README.md documents the same names and JSON schemas —
# keep the two lists in sync (test_system checks --list works).
BENCHMARKS = [
    ("bench_writer", "python benchmarks/bench_writer.py", "BENCH_writer.json"),
    ("bench_reader", "python benchmarks/bench_reader.py", "BENCH_reader.json"),
    ("bench_codec", "python benchmarks/bench_codec.py", "BENCH_codec.json"),
    ("bench_io", "python benchmarks/bench_io.py", "BENCH_io.json"),
    ("bench_fault", "python benchmarks/bench_fault.py", "BENCH_fault.json"),
    ("bench_mpwrite", "python benchmarks/bench_mpwrite.py",
     "BENCH_mpwrite.json"),
    ("bench_pipeline", "python benchmarks/bench_pipeline.py",
     "BENCH_pipeline.json"),
    ("bench_remote", "python benchmarks/bench_remote.py",
     "BENCH_remote.json"),
    ("bench_skim", "python benchmarks/bench_skim.py", "BENCH_skim.json"),
    ("fig2_devnull", "python -m benchmarks.run", "stdout CSV row"),
    ("fig3_ssd", "python -m benchmarks.run", "stdout CSV row"),
    ("fig4_hdd", "python -m benchmarks.run", "stdout CSV row"),
    ("fig5_skim", "python -m benchmarks.run", "stdout CSV row"),
    ("roofline", "python -m benchmarks.run", "stdout CSV row"),
]


def list_benchmarks() -> None:
    print(f"{'name':14s}  {'run with':36s}  emits")
    for name, cmd, emits in BENCHMARKS:
        print(f"{name:14s}  {cmd:36s}  {emits}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--entries", type=int, default=None)
    ap.add_argument("--list", action="store_true",
                    help="enumerate every benchmark + emitted JSON and exit")
    args = ap.parse_args()
    if args.list:
        list_benchmarks()
        return
    entries = args.entries or (100_000 if args.quick else 200_000)
    events = 3_000 if args.quick else 8_000

    rows = []

    print("\n################ fig2: /dev/null weak scaling ################")
    f2 = fig2_devnull.run(entries)
    one = next(r for r in f2["measured"]
               if r["config"] == "buffered" and r["threads"] == 1)
    us_per_entry = one["wall_s"] / entries * 1e6
    rows.append(("fig2_devnull", f"{us_per_entry:.3f}",
                 f"buffered_64t_speedup={f2['speedup_64t']['buffered']:.1f}x"
                 f"_paper=45.4x;lock_ratio={f2['lock_ratio']:.0f}x_paper~90x"))

    print("\n################ fig3: SSD ################")
    f3 = fig3_ssd.run()
    rows.append(("fig3_ssd", f"{us_per_entry:.3f}",
                 f"peak_frac_of_771MBs={f3['peak_fraction_of_limit']:.2f}"
                 f"_paper=0.91"))

    print("\n################ fig4: HDD ################")
    f4 = fig4_hdd.run()
    rows.append(("fig4_hdd", f"{us_per_entry:.3f}",
                 f"uncompressed_2t_frac={f4['uncompressed_at_2t_frac']:.2f}"
                 f"_paper~0.83"))

    print("\n################ fig5: AGC skimming ################")
    f5 = fig5_skim.run(events)
    par1 = next(r for r in f5["measured"]["runs"]
                if r["strategy"] == "parallel" and r["threads"] == 1)
    us_per_event_in = par1["wall_s"] / (events * 9 * 4) * 1e6
    sp = f5["projected"]["parallel"]["speedup"]
    p128 = sp.get(128, sp.get("128"))
    rows.append(("fig5_skim", f"{us_per_event_in:.3f}",
                 f"parallel_128t_projected={p128}x_paper=42.7x"))

    print("\n################ roofline (dry-run) ################")
    try:
        recs = roofline.load("singlepod")
        ok = [r for r in recs if r.get("status") == "ok"]
        if ok:
            fracs = [roofline.roofline_fraction(r) for r in ok]
            best = max(fracs)
            worst = min(fracs)
            picks = roofline.pick_hillclimb_cells()
            rows.append(("roofline", f"{len(ok)}",
                         f"cells_ok={len(ok)};frac_best={best:.3f};"
                         f"frac_worst={worst:.3f}"))
            print(f"{len(ok)} cells; roofline fraction "
                  f"{worst:.3f}..{best:.3f}")
            for label, rec in picks.items():
                print(f"  {label}: {rec['arch']} x {rec['shape']}")
        else:
            rows.append(("roofline", "0", "run_repro.launch.dryrun_first"))
    except Exception as e:
        rows.append(("roofline", "0", f"unavailable:{type(e).__name__}"))

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
