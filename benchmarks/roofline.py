"""Roofline reporter: dry-run JSONs -> the §Roofline table.

Per (arch x shape x mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS(6·N·D / 6·N_active·D), useful fraction of compiled
compute, and the roofline fraction (useful compute time / dominant term).

Also ranks cells to pick the three hillclimb targets: worst roofline
fraction, most collective-bound, most representative of the paper's
technique (the train cell with the highest checkpoint-relevant state).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

DRYRUN = Path(__file__).parent / "results" / "dryrun"

from repro.launch.hlo_analysis import PEAK_FLOPS

_IDEAL_CACHE: Dict[tuple, float] = {}


def _ideal_bytes(rec: dict) -> Optional[float]:
    """Irreducible decode bytes/device: params + cache read once."""
    key = (rec["arch"], rec["shape"], rec["n_chips"])
    if key in _IDEAL_CACHE:
        return _IDEAL_CACHE[key]
    try:
        import jax
        import numpy as np
        from repro.configs import SHAPES_BY_NAME, get_arch
        from repro.models.registry import build
        bundle = build(get_arch(rec["arch"]))
        cell = SHAPES_BY_NAME[rec["shape"]]
        pb = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(bundle.param_shapes()))
        cb = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                 for l in jax.tree_util.tree_leaves(
                     jax.eval_shape(lambda: bundle.init_cache(
                         cell.global_batch, cell.seq_len))))
        val = (pb + cb) / rec["n_chips"]
    except Exception:
        val = None
    _IDEAL_CACHE[key] = val
    return val


def load(mesh: str = "singlepod") -> List[dict]:
    out = []
    for f in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        out.append(json.loads(f.read_text()))
    return out


def roofline_fraction(rec: dict) -> Optional[float]:
    """Fraction of the dominant roofline actually doing irreducible work.

    Train/prefill (compute-meaningful): useful-model-compute time /
    dominant-term time.  Decode (bandwidth-bound by nature): irreducible
    bytes (params + cache read once) / compiled bytes — how close the
    step is to the memory-bandwidth roofline.
    """
    if rec.get("status") != "ok":
        return None
    r = rec["roofline"]
    if rec["shape"].startswith(("decode", "long")):
        ideal = rec.get("ideal_bytes_per_device") or _ideal_bytes(rec)
        if ideal and rec.get("hlo_bytes"):
            return min(1.0, ideal / rec["hlo_bytes"])
        # fall back to compute fraction
    t_dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
    t_useful = rec["model_flops_per_device"] / PEAK_FLOPS
    return t_useful / t_dom if t_dom else None


def table(mesh: str = "singlepod") -> str:
    rows = []
    head = (f"| arch | shape | compute_s | memory_s | collective_s | "
            f"dominant | useful_frac | roofline_frac |")
    sep = "|" + "---|" * 8
    rows.append(head)
    rows.append(sep)
    for rec in load(mesh):
        if rec["status"] == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                        f"{rec['reason'].split(':')[0]} | — | — |")
            continue
        if rec["status"] != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | ERROR | | | | | |")
            continue
        r = rec["roofline"]
        rf = roofline_fraction(rec)
        uf = rec.get("useful_fraction")
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {uf:.3f} | {rf:.3f} |"
        )
    return "\n".join(rows)


def pick_hillclimb_cells(mesh: str = "singlepod") -> Dict[str, dict]:
    recs = [r for r in load(mesh) if r.get("status") == "ok"]
    by_frac = sorted(recs, key=lambda r: roofline_fraction(r) or 1.0)
    worst = by_frac[0]

    def coll_share(r):
        rr = r["roofline"]
        tot = rr["compute_s"] + rr["memory_s"] + rr["collective_s"]
        if max(rr["compute_s"], rr["memory_s"], rr["collective_s"]) < 0.01:
            return 0.0  # degenerate cell (e.g. B=1 decode): not meaningful
        return rr["collective_s"] / tot if tot else 0.0

    most_coll = max(recs, key=coll_share)
    # most representative of the paper's technique: the largest train cell
    # (checkpoint state = the paper's workload; deepseek-67b train is the
    # flagship) — the train cell with the largest model_flops
    train = [r for r in recs if r["shape"] == "train_4k"]
    flagship = max(train, key=lambda r: r["model_flops"])
    return {"worst_roofline": worst, "most_collective_bound": most_coll,
            "paper_flagship": flagship}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="singlepod",
                    choices=["singlepod", "multipod"])
    args = ap.parse_args()
    print(table(args.mesh))
    print()
    picks = pick_hillclimb_cells(args.mesh)
    for label, rec in picks.items():
        print(f"{label}: {rec['arch']} x {rec['shape']} "
              f"(dominant={rec['roofline']['dominant']}, "
              f"roofline_frac={roofline_fraction(rec):.3f})")


if __name__ == "__main__":
    main()
