"""Write-path throughput benchmark — the perf trajectory for this repo.

Measures, on the paper's synthetic nested-event workload
(``{id: int64, vals: float32[k]}, k ~ Poisson(5)``):

 1. **fill+seal** single-producer throughput of the rebuilt engine
    (contiguous ColumnBuffers, column-batched preconditioning, shared
    compression pool, double-buffered pipelined sealing) against the
    **actual pre-PR code path** (vendored verbatim in
    ``_legacy_seed_writer.py``: list-of-chunks fill, ``np.concatenate``
    at seal, serial per-page compression, ``b"".join`` assembly), at the
    same codec/level, checksum, page and cluster sizes — for two value
    distributions (incompressible uniform floats and compressible
    detector-style quantized floats) and for the paper's uncompressed
    configuration.
 2. a writer matrix: sequential vs parallel, buffered vs unbuffered,
    pipelined vs synchronous sealing, 1-16 producers, into /dev/null.

The report embeds a runtime *parallel-capacity probe* (measured 2-thread
zlib scaling): pooled/pipelined speedups are bounded by it, and shared CI
containers often expose far less than ``cpu_count`` suggests.

Emits ``BENCH_writer.json`` (repo root by default).

Run:  PYTHONPATH=src python benchmarks/bench_writer.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time
from pathlib import Path

from _harness import (  # noqa: F401 (re-exported for older callers)
    EVENT_SCHEMA, REPO_ROOT, WORKLOADS, hep_batch,
    prebuild as _prebuild, probe_parallel_capacity, synth_batch,
)

from repro.core import (  # noqa: E402
    DevNullSink, ParallelWriter, SequentialWriter, WriteOptions,
)
from repro.core import compression as comp  # noqa: E402

from _legacy_seed_writer import SeedSequentialWriter  # noqa: E402


# ---------------------------------------------------------------------------
# fill+seal: pre-PR engine vs rebuilt engine


def bench_seed_fill_seal(batches, cluster_bytes, codec_id, level, page_size,
                         repeats) -> float:
    best = float("inf")
    for _ in range(repeats):
        w = SeedSequentialWriter(
            EVENT_SCHEMA, DevNullSink(), page_size=page_size, codec=codec_id,
            level=level, cluster_bytes=cluster_bytes,
        )
        t0 = time.perf_counter()
        for b in batches:
            w.fill_batch(b)
        w.close()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_new_fill_seal(batches, cluster_bytes, codec, level, page_size,
                        imt_workers, pipelined, repeats):
    best, phases = float("inf"), None
    for _ in range(repeats):
        opts = WriteOptions(codec=codec, level=level,
                            cluster_bytes=cluster_bytes, page_size=page_size,
                            imt_workers=imt_workers, pipelined_seal=pipelined)
        w = SequentialWriter(EVENT_SCHEMA, DevNullSink(), opts)
        t0 = time.perf_counter()
        for b in batches:
            w.fill_batch(b)
        w.close()
        wall = time.perf_counter() - t0
        if wall < best:
            best, phases = wall, w.stats.phases_ms()
    return best, phases


# ---------------------------------------------------------------------------
# writer matrix


def bench_matrix_run(mode: str, producers: int, batches_per_producer,
                     opts: WriteOptions) -> dict:
    t0 = time.perf_counter()
    if mode == "sequential":
        w = SequentialWriter(EVENT_SCHEMA, DevNullSink(), opts)
        for b in batches_per_producer[0]:
            w.fill_batch(b)
        w.close()
    else:
        w = ParallelWriter(EVENT_SCHEMA, DevNullSink(), opts)

        def worker(tid: int):
            ctx = w.create_fill_context()
            for b in batches_per_producer[tid]:
                ctx.fill_batch(b)
            ctx.close()

        ts = [threading.Thread(target=worker, args=(t,)) for t in range(producers)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        w.close()
    wall = time.perf_counter() - t0
    s = w.stats
    return {
        "mode": mode, "producers": producers,
        "buffered": opts.buffered, "pipelined_seal": opts.pipelined_seal,
        "wall_s": round(wall, 4),
        "entries": s.entries,
        "entries_per_s": round(s.entries / wall),
        "mb_s_uncompressed": round(s.uncompressed_bytes / wall / 1e6, 1),
        "mb_s_compressed": round(s.compressed_bytes / wall / 1e6, 1),
        "lock_acquisitions": s.lock.acquisitions,
        "lock_contended": s.lock.contended,
        "phases_ms": {k: round(v, 2) for k, v in s.phases_ms().items()},
    }


def run(entries: int, quick: bool, out_path: Path) -> dict:
    cluster_bytes = 1 << 20
    page_size = 64 * 1024
    workers = min(4, max(2, (os.cpu_count() or 2)))
    producer_counts = [1, 2] if quick else [1, 2, 4, 8, 16]
    repeats = 2 if quick else 4

    out: dict = {
        "benchmark": "bench_writer",
        "schema": "event{id:int64, vals:float32[k~Poisson(5)]}",
        "cluster_bytes": cluster_bytes, "page_size": page_size,
        "entries": entries, "cpu_count": os.cpu_count(),
        "imt_workers": workers,
        "parallel_capacity_2t": probe_parallel_capacity(),
    }
    print(f"parallel capacity probe (2-thread zlib scaling): "
          f"{out['parallel_capacity_2t']}x of ideal 2.0")

    # -- 1. fill+seal: pre-PR seed code vs rebuilt engine -------------------
    print("== single-producer fill+seal: seed code path vs rebuilt engine ==")
    out["fill_seal"] = {}
    best_speedup = 0.0
    for workload, codec, level in [
        ("uniform", "zlib", 1),
        ("hep", "zlib", 1),
        ("uniform", "none", -1),
    ]:
        key = f"{workload}/{codec}"
        batches = _prebuild(workload, entries, 50_000)
        n_total = sum(b.n_entries for b in batches)
        nbytes = sum(sum(a.nbytes for a in b.data.values()) for b in batches)
        cid = comp.codec_id(codec)
        seed_wall = bench_seed_fill_seal(batches, cluster_bytes, cid, level,
                                         page_size, repeats)
        sync_wall, sync_ph = bench_new_fill_seal(
            batches, cluster_bytes, codec, level, page_size, 0, False, repeats)
        pipe_wall, pipe_ph = bench_new_fill_seal(
            batches, cluster_bytes, codec, level, page_size, workers, True,
            repeats)
        new_wall = min(sync_wall, pipe_wall)
        speedup = seed_wall / new_wall
        best_speedup = max(best_speedup, speedup)
        out["fill_seal"][key] = {
            "seed": {"wall_s": round(seed_wall, 4),
                     "entries_per_s": round(n_total / seed_wall),
                     "mb_s": round(nbytes / seed_wall / 1e6, 1)},
            "new_sync": {"wall_s": round(sync_wall, 4),
                         "entries_per_s": round(n_total / sync_wall),
                         "mb_s": round(nbytes / sync_wall / 1e6, 1),
                         "phases_ms": {k: round(v, 1) for k, v in sync_ph.items()}},
            "new_pipelined_pooled": {
                "wall_s": round(pipe_wall, 4),
                "entries_per_s": round(n_total / pipe_wall),
                "mb_s": round(nbytes / pipe_wall / 1e6, 1),
                "phases_ms": {k: round(v, 1) for k, v in pipe_ph.items()}},
            "speedup_vs_seed": round(speedup, 3),
        }
        print(f"  {key:14s} seed {n_total/seed_wall:9.0f} e/s | "
              f"new sync {n_total/sync_wall:9.0f} e/s | "
              f"pipe+pool {n_total/pipe_wall:9.0f} e/s | "
              f"speedup {speedup:.2f}x")
    out["speedup_vs_legacy"] = round(best_speedup, 3)
    print(f"  best speedup vs pre-PR code path: {best_speedup:.2f}x "
          f"(parallel capacity {out['parallel_capacity_2t']}x)")

    # -- 2. writer matrix ---------------------------------------------------
    print("== writer matrix (DevNull, hep workload) ==")
    out["matrix"] = []
    matrix_entries = max(entries // 4, 20_000)
    for producers in producer_counts:
        per = [_prebuild("hep", matrix_entries, 25_000)
               for _ in range(producers)]
        configs = [
            ("parallel", True, False),
            ("parallel", True, True),
            ("parallel", False, False),
        ]
        if producers == 1:
            configs = [("sequential", True, False),
                       ("sequential", True, True)] + configs
        for mode, buffered, pipelined in configs:
            opts = WriteOptions(
                codec="zlib", level=1, cluster_bytes=cluster_bytes,
                page_size=page_size, buffered=buffered,
                pipelined_seal=pipelined,
                imt_workers=workers if (pipelined or mode == "sequential") else 0,
            )
            rec = bench_matrix_run(mode, producers, per, opts)
            out["matrix"].append(rec)
            print(f"  {mode:10s} p={producers:2d} buffered={int(buffered)} "
                  f"pipelined={int(pipelined)}  "
                  f"{rec['entries_per_s']:10d} entries/s "
                  f"{rec['mb_s_uncompressed']:7.1f} MB/s "
                  f"locks={rec['lock_acquisitions']}")

    out_path.write_text(json.dumps(out, indent=1))
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--entries", type=int, default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small workload for CI smoke runs")
    ap.add_argument("--out", type=str,
                    default=str(REPO_ROOT / "BENCH_writer.json"))
    args = ap.parse_args()
    entries = args.entries or (60_000 if args.quick else 400_000)
    run(entries, args.quick, Path(args.out))


if __name__ == "__main__":
    main()
