"""Paper Fig. 5: AGC dataset-skimming speedups across writing strategies.

MEASURED part (this container): real runs of all five strategies on a
synthetic 9-partition dataset at 1/2/4 threads; equality of outputs; lock
statistics; the serial fraction of each strategy (merge tail, IMT serial
remainder, parallel-writer lock share).

PROJECTED part: Amdahl projection of each strategy to 128 threads from the
measured serial fractions, compared against the paper's endpoints:
IMT plateau 5.7x, TBufferMerger peaks ~32t, separate-files and parallel
writing both ~42.7x @128t (equal scalability — the paper's headline),
parallel avoiding the merge tail and 2x transient storage.

Run:  PYTHONPATH=src:. python -m benchmarks.fig5_skim [--events 8000]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.skim import STRATEGIES, make_agc_dataset, skim_partitions

RESULTS = Path(__file__).parent / "results"


def measure(events: int, threads=(1, 2, 4)) -> dict:
    work = tempfile.mkdtemp(prefix="fig5_")
    parts = make_agc_dataset(os.path.join(work, "in"), n_partitions=9,
                             files_per_partition=4, events_per_file=events)
    in_bytes = sum(os.path.getsize(f) for fs in parts.values() for f in fs)
    out = {"input_mb": in_bytes / 1e6, "runs": [], "kept": None}

    for strat in STRATEGIES:
        for n in threads:
            dst = os.path.join(work, f"{strat}_{n}")
            t0 = time.perf_counter()
            res = skim_partitions(parts, dst, strat, n_threads=n)
            wall = time.perf_counter() - t0
            rec = {"strategy": strat, "threads": n,
                   "wall_s": round(wall, 3), "kept": res["kept_events"]}
            out["runs"].append(rec)
            if out["kept"] is None:
                out["kept"] = res["kept_events"]
            assert res["kept_events"] == out["kept"], "strategies disagree"
            print(f"  {strat:15s} t={n}  {wall:6.2f}s  kept={res['kept_events']}")
    shutil.rmtree(work, ignore_errors=True)
    return out


def project(measured: dict) -> dict:
    """Amdahl projection from measured 1-thread serial shares.

    Strategy serial fractions (of single-thread wall time):
      imt          — skim+fill pipeline stays serial; only page compression
                     parallelizes (measured compression share ~55% of the
                     writer path => plateau, paper 5.7x)
      separate     — fully parallel skim + a serial merge tail (merge wall
                     measured as extra time vs separate-null)
      buffermerger — parallel skim + serialized cluster-copy under the
                     merge lock
      parallel     — parallel skim + the writer's critical section
    """
    one = {r["strategy"]: r["wall_s"] for r in measured["runs"]
           if r["threads"] == 1}
    t_null = one["separate-null"]
    serial = {
        # separate-null is the pure-compute ceiling; strategy serial share =
        # extra single-thread time over it, as a fraction of its own time.
        s: max(0.0, (one[s] - t_null) / one[s]) for s in one
    }
    # IMT additionally serializes everything but page compression (~45%)
    serial["imt"] = max(serial["imt"], 0.45)
    proj = {}
    for s, f in serial.items():
        speed = {n: 1.0 / (f + (1.0 - f) / n) for n in (8, 32, 64, 128)}
        proj[s] = {"serial_frac": round(f, 4),
                   "speedup": {k: round(v, 1) for k, v in speed.items()}}
        print(f"  {s:15s} serial={f:6.2%}  "
              + "  ".join(f"{n}t:{speed[n]:5.1f}x" for n in (8, 32, 128)))
    return proj


def run(events: int = 6000) -> dict:
    print("== measured (1-core container) ==")
    measured = measure(events)
    print("== Amdahl projection from measured serial fractions ==")
    projected = project(measured)
    out = {"measured": measured, "projected": projected}
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "fig5_skim.json").write_text(json.dumps(out, indent=1))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=6000)
    args = ap.parse_args()
    run(args.events)


if __name__ == "__main__":
    main()
