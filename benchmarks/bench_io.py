"""I/O-engine benchmark — the commit path across sinks × submission modes.

Measures, on the paper's synthetic nested-event workload at codec
``none`` (so the commit path — serialize, assemble/gather, pwrite — is
the whole story, with no entropy-coder noise):

 1. the **commit matrix** — DevNull / Memory sinks × {assembled
    monolithic pwrite, scatter-gather pwritev (buffer pool on and off),
    scatter + striped parallel pwrite, scatter + write-behind through
    the emulated submission ring}: single-producer fill+seal+commit wall
    time, the phase breakdown, and each cell's buffer-pool hit rate.
    Scatter eliminates the cluster-assembly memcpy; the pool eliminates
    the per-detach allocation it left behind; striping turns one big
    extent write into parallel sub-extent jobs; the ring turns
    per-stripe executor futures into deque appends (DESIGN.md §6.7/§6.8).
 2. **write-behind vs a throttled device** — a ThrottledSink whose
    bandwidth sits ABOVE the producer's aggregate rate (storage can keep
    up, but a synchronous commit still serializes producer and device).
    Write-behind must hold fill+seal throughput within ~10% of the
    /dev/null ceiling while the synchronous path pays the full device
    time on the producer's clock.  Both submission backends are
    measured: the ring (default) and the PR-4 executor path
    (``io_ring="off"``).
 3. a **parallel-writer cell** — 4 producers into one MemorySink file,
    assembled vs the full engine (scatter + ring write-behind).

Every configuration's MemorySink file is asserted **byte-identical** to
the assembled-path reference file, and the reference is cross-checked
cluster by cluster through the vendored pre-PR-2 seed reader — the
engine changes how bytes are *submitted*, never what they are.

Emits ``BENCH_io.json`` (repo root by default); the field schema is
documented in ``benchmarks/README.md``.

Run:  PYTHONPATH=src python benchmarks/bench_io.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from _harness import (  # noqa: F401
    EVENT_SCHEMA, REPO_ROOT, prebuild, probe_parallel_capacity,
)
from _legacy_seed_reader import SeedRNTJReader

from repro.core import (  # noqa: E402
    DevNullSink, MemorySink, ParallelWriter, RNTJReader, SequentialWriter,
    ThrottledSink, WriteOptions,
)

# big pages, moderate clusters: the commit path moves MB-scale extents
# (where assembly memcpys and monolithic pwrites hurt) while leaving
# enough commit points for write-behind overlap to matter
PAGE = 256 * 1024
CLUSTER = 2 * 1024 * 1024

MODES: Dict[str, dict] = {
    "assembled": dict(scatter_commit=False),
    "scatter": dict(scatter_commit=True),
    "scatter+nopool": dict(scatter_commit=True, buffer_pool_bytes=0),
    "scatter+striped": dict(scatter_commit=True, io_stripe_bytes=512 * 1024,
                            io_workers=4),
    # async submission: queued commits through the emulated ring (one
    # drain worker — a single sink stream needs no more)
    "scatter+ring": dict(scatter_commit=True,
                         io_inflight_bytes=32 * 1024 * 1024,
                         io_ring="emulated", io_workers=1),
}


def base_options(**over) -> WriteOptions:
    # precondition=False + codec none + checksum off isolates the commit
    # layer: fill, serialize-plan, (assemble?), pwrite — the bytes the
    # paper's §5 storage wall actually moves, with no codec/encoding/CRC
    # CPU on top of them
    opts = dict(codec="none", page_size=PAGE, cluster_bytes=CLUSTER,
                precondition=False, checksum=False)
    opts.update(over)
    return WriteOptions(**opts)


def fill_all(writer, batches) -> float:
    t0 = time.perf_counter()
    for b in batches:
        writer.fill_batch(b)
    writer.close()
    return time.perf_counter() - t0


def run_single(sink_factory, batches, opts: WriteOptions, repeats: int):
    best, stats = float("inf"), None
    for _ in range(repeats):
        w = SequentialWriter(EVENT_SCHEMA, sink_factory(), opts)
        wall = fill_all(w, batches)
        if wall < best:
            best, stats = wall, w.stats
    return best, stats


def run_interleaved(sink_factory, batches, configs: Dict[str, WriteOptions],
                    repeats: int):
    """Best-of-N walls with the configs interleaved per round, so slow
    drift on a shared container cancels out of their ratios."""
    best = {name: (float("inf"), None) for name in configs}
    for _ in range(repeats):
        for name, opts in configs.items():
            w = SequentialWriter(EVENT_SCHEMA, sink_factory(), opts)
            wall = fill_all(w, batches)
            if wall < best[name][0]:
                best[name] = (wall, w.stats)
    return best


def reference_file(batches, opts: WriteOptions) -> MemorySink:
    sink = MemorySink()
    w = SequentialWriter(EVENT_SCHEMA, sink, opts)
    fill_all(w, batches)
    return sink


def assert_identical(ref: MemorySink, sink: MemorySink, label: str) -> None:
    if bytes(ref.buf) != bytes(sink.buf):
        raise SystemExit(f"byte-identity violated: {label}")


def seed_reader_crosscheck(sink: MemorySink) -> int:
    """The unmodified pre-PR-2 seed reader must fully decode the file and
    agree with the read engine, cluster by cluster."""
    seed = SeedRNTJReader(sink)
    engine = RNTJReader(sink)
    clusters = engine.n_clusters
    for ci in range(clusters):
        a, b = seed.read_cluster(ci), engine.read_cluster(ci)
        for k in b:
            if not np.array_equal(a[k], b[k]):
                raise SystemExit(f"seed reader mismatch: cluster {ci} col {k}")
    return clusters


# ---------------------------------------------------------------------------
# 1. the commit matrix


def run_matrix(batches, nbytes: int, repeats: int, out: dict) -> None:
    print("== commit matrix: sink x submission mode (codec none) ==")
    ref = reference_file(batches, base_options(**MODES["assembled"]))
    clusters = seed_reader_crosscheck(ref)
    print(f"  reference file: {len(ref.buf) / 1e6:.1f} MB, {clusters} "
          "clusters, seed-reader verified")
    out["matrix"] = []
    # preallocated memory sink: the matrix measures the commit path's
    # copies/submissions, not bytearray realloc traffic
    cap = int(nbytes * 1.25)
    sinks = (("devnull", DevNullSink), ("memory", lambda: MemorySink(cap)))
    for sink_name, factory in sinks:
        configs = {m: base_options(**over) for m, over in MODES.items()}
        results = run_interleaved(factory, batches, configs, repeats)
        for mode, (wall, stats) in results.items():
            d = stats.as_dict()
            pool_total = d["pool_hits"] + d["pool_misses"]
            rec = {
                "sink": sink_name,
                "mode": mode,
                "wall_s": round(wall, 4),
                "mb_s": round(nbytes / wall / 1e6, 1),
                "seal_ms": round(d["seal_ms"], 1),
                "commit_ms": round(d["commit_ms"], 1),
                "io_ms": round(d["io_ms"], 1),
                "io_submit_ms": round(d["io_submit_ms"], 2),
                "write_calls": d["write_calls"],
                "writev_calls": d["writev_calls"],
                "pool_hit_rate": (
                    round(d["pool_hits"] / pool_total, 3) if pool_total else None
                ),
            }
            if sink_name == "memory":
                sink = MemorySink()
                fill_all(SequentialWriter(EVENT_SCHEMA, sink,
                                          configs[mode]), batches)
                assert_identical(ref, sink, f"{sink_name}/{mode}")
                rec["byte_identical"] = True
            out["matrix"].append(rec)
            print(f"  {sink_name:7s} {mode:16s} {rec['mb_s']:8.1f} MB/s  "
                  f"seal {rec['seal_ms']:7.1f} ms  commit {rec['commit_ms']:6.1f} ms")

    def wall(sink, mode):
        return next(r for r in out["matrix"]
                    if r["sink"] == sink and r["mode"] == mode)["wall_s"]

    # engine-best vs the assembled monolithic pwrite: striping only pays
    # where the write itself has cost (memory/file); on devnull the win
    # is the eliminated assembly memcpy alone
    engine_modes = ("scatter", "scatter+striped", "scatter+ring")
    out["speedup_engine_best"] = {
        s: round(
            wall(s, "assembled") / min(wall(s, m) for m in engine_modes), 3)
        for s in ("devnull", "memory")
    }
    out["speedup_scatter_striped"] = {
        s: round(wall(s, "assembled") / wall(s, "scatter+striped"), 3)
        for s in ("devnull", "memory")
    }
    out["speedup_pool"] = {
        s: round(wall(s, "scatter+nopool") / wall(s, "scatter"), 3)
        for s in ("devnull", "memory")
    }
    out["speedup_ring"] = {
        s: round(wall(s, "assembled") / wall(s, "scatter+ring"), 3)
        for s in ("devnull", "memory")
    }
    for s, x in out["speedup_engine_best"].items():
        print(f"  {s}: engine best vs assembled monolithic = {x:.2f}x "
              f"(pool {out['speedup_pool'][s]:.2f}x, "
              f"ring {out['speedup_ring'][s]:.2f}x)")


# ---------------------------------------------------------------------------
# 2. write-behind vs a throttled device


def run_write_behind(batches, nbytes: int, repeats: int, out: dict) -> None:
    print("== write-behind: throttled sink above the producer rate ==")
    # realistic producer config (checksums on, like every default writer):
    # the question is purely whether queued draining hides device time
    # realistic checksummed producer, 8 MB clusters: fewer/longer device
    # sleeps, so the ThrottledSink model's per-sleep scheduler overshoot
    # (0.5-2 ms on loaded CI boxes) amortizes out of the comparison
    wb_base = dict(**MODES["scatter"], checksum=True,
                   cluster_bytes=8 * 1024 * 1024)
    probe_wall, _ = run_single(
        DevNullSink, batches, base_options(**wb_base), max(1, repeats // 2)
    )
    bw = 2.0 * nbytes / probe_wall  # storage CAN keep up — only overlap
    print(f"  producer rate {nbytes / probe_wall / 1e6:.0f} MB/s -> "
          f"throttle at {bw / 1e6:.0f} MB/s")

    def throttled():
        return ThrottledSink(DevNullSink(), bw=bw)

    # all configs interleaved per round (incl. the devnull ceiling), so
    # box drift cancels out of the ratios the acceptance criterion
    # compares.  Both async submission backends are measured: the ring
    # (default; one deque append per extent) and the PR-4 executor path.
    opts_by_name = {
        "devnull": base_options(**wb_base),
        "sync": base_options(**wb_base),
        # one drain worker: a single device stream needs no more, and on
        # quota-throttled CI boxes every extra wakeup steals producer time
        "write_behind": base_options(**wb_base,
                                     io_inflight_bytes=32 * 1024 * 1024,
                                     io_ring="emulated", io_workers=1),
        "write_behind_executor": base_options(**wb_base,
                                              io_inflight_bytes=32 * 1024 * 1024,
                                              io_ring="off", io_workers=1),
    }
    best = {name: (float("inf"), None) for name in opts_by_name}
    for _ in range(repeats):
        for name, opts in opts_by_name.items():
            sink = DevNullSink() if name == "devnull" else throttled()
            w = SequentialWriter(EVENT_SCHEMA, sink, opts)
            wall = fill_all(w, batches)
            if wall < best[name][0]:
                best[name] = (wall, w.stats)
    devnull_wall, _ = best["devnull"]
    sync_wall, _ = best["sync"]
    wb_wall, wb_stats = best["write_behind"]
    exec_wall, _ = best["write_behind_executor"]
    d = wb_stats.as_dict()
    pool_total = d["pool_hits"] + d["pool_misses"]
    out["write_behind"] = {
        "throttle_mb_s": round(bw / 1e6, 1),
        "devnull_wall_s": round(devnull_wall, 4),
        "sync_wall_s": round(sync_wall, 4),
        "write_behind_wall_s": round(wb_wall, 4),
        "write_behind_executor_wall_s": round(exec_wall, 4),
        "vs_devnull": round(wb_wall / devnull_wall, 3),
        "executor_vs_devnull": round(exec_wall / devnull_wall, 3),
        "sync_vs_devnull": round(sync_wall / devnull_wall, 3),
        "io_stall_ms": round(d["io_stall_ms"], 1),
        "io_submit_ms": round(d["io_submit_ms"], 2),
        "io_jobs": d["io_jobs"],
        "io_inflight_peak_bytes": d["io_inflight_peak_bytes"],
        "pool_hit_rate": (
            round(d["pool_hits"] / pool_total, 3) if pool_total else None
        ),
    }
    print(f"  devnull {devnull_wall:.3f}s | sync {sync_wall:.3f}s "
          f"({sync_wall / devnull_wall:.2f}x) | ring {wb_wall:.3f}s "
          f"({wb_wall / devnull_wall:.2f}x of devnull) | executor "
          f"{exec_wall:.3f}s ({exec_wall / devnull_wall:.2f}x)")


# ---------------------------------------------------------------------------
# 3. parallel writers through the engine


def run_parallel(batches, nbytes: int, n_threads: int, repeats: int,
                 out: dict) -> None:
    print(f"== parallel writer x{n_threads}: full engine vs assembled ==")

    def run(opts: WriteOptions) -> float:
        sink = MemorySink()
        w = ParallelWriter(EVENT_SCHEMA, sink, opts)
        chunks = [batches[i::n_threads] for i in range(n_threads)]

        def produce(mine):
            ctx = w.create_fill_context()
            for b in mine:
                ctx.fill_batch(b)
            ctx.close()

        ts = [threading.Thread(target=produce, args=(c,)) for c in chunks]
        t0 = time.perf_counter()
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        w.close()
        wall = time.perf_counter() - t0
        # sanity: the parallel file stays readable with all entries
        assert RNTJReader(sink).n_entries == sum(b.n_entries for b in batches)
        return wall

    configs = {
        "assembled": base_options(**MODES["assembled"]),
        "engine": base_options(**MODES["scatter"],
                               io_inflight_bytes=8 * CLUSTER,
                               io_workers=1),
    }
    walls = {name: float("inf") for name in configs}
    for _ in range(repeats):  # interleaved: drift cancels out of the ratio
        for name, opts in configs.items():
            walls[name] = min(walls[name], run(opts))
    plain, engine = walls["assembled"], walls["engine"]
    out["parallel"] = {
        "threads": n_threads,
        "assembled_mb_s": round(nbytes / plain / 1e6, 1),
        "engine_mb_s": round(nbytes / engine / 1e6, 1),
        "speedup": round(plain / engine, 3),
    }
    print(f"  assembled {nbytes / plain / 1e6:8.1f} MB/s")
    print(f"  engine    {nbytes / engine / 1e6:8.1f} MB/s "
          f"({plain / engine:.2f}x)")


def run(entries: int, quick: bool, out_path: Path) -> dict:
    repeats = 2 if quick else 4
    batches = prebuild("uniform", entries, 50_000)
    nbytes = sum(sum(a.nbytes for a in b.data.values()) for b in batches)
    out: dict = {
        "benchmark": "bench_io",
        "entries": entries,
        "uncompressed_mb": round(nbytes / 1e6, 1),
        "page_bytes": PAGE,
        "cluster_bytes": CLUSTER,
        "cpu_count": os.cpu_count(),
        "parallel_capacity_2t": probe_parallel_capacity(),
    }
    print(f"workload: {out['uncompressed_mb']} MB uncompressed, "
          f"parallel capacity {out['parallel_capacity_2t']}x")
    run_matrix(batches, nbytes, repeats, out)
    run_write_behind(batches, nbytes, repeats, out)
    run_parallel(batches, nbytes, min(4, os.cpu_count() or 2), repeats, out)
    out_path.write_text(json.dumps(out, indent=1))
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--entries", type=int, default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small workload for CI smoke runs")
    ap.add_argument("--out", type=str,
                    default=str(REPO_ROOT / "BENCH_io.json"))
    args = ap.parse_args()
    entries = args.entries or (300_000 if args.quick else 2_500_000)
    run(entries, args.quick, Path(args.out))


if __name__ == "__main__":
    main()
