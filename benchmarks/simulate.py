"""Calibrated discrete-event simulator of the parallel-writing protocol.

This container has ONE core, so thread-scaling curves cannot be measured
directly.  What CAN be measured for real (benchmarks/fig2_devnull.py):

  * per-thread serialization+compression cost (seal time / byte),
  * the critical-section cost per commit (lock-held time),
  * per-page commit cost (unbuffered mode),
  * lock acquisition / contention counts (the paper's futex diagnosis),
  * device bandwidth model parameters (paper's fio numbers).

This simulator replays the exact writer protocol — per-thread cluster
preparation, a single mutex for reserve+metadata(+write), optional
fallocate and write-outside-lock — over N cores with those measured
constants, reproducing the SHAPE of the paper's Figs. 2-4 (weak scaling,
lock-contention collapse of the unbuffered mode, device-bandwidth
plateaus).  Every calibration constant is recorded next to the results.

Model:
  * n_threads threads on n_cores cores; compute (seal/compress) time
    scales by core oversubscription factor max(1, n_threads/n_cores);
  * one mutex: commits serialize; FIFO service;
  * device: unlimited (/dev/null) or a shared channel with bandwidth bw
    (bw_prealloc when fallocated) — writes serialize at the device;
  * buffered: 1 commit per cluster; unbuffered: 1 lock per page (commit
    cost per page) + metadata commit per cluster.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(frozen=True)
class Costs:
    """Calibrated single-thread costs (seconds, bytes)."""

    seal_s_per_byte: float          # serialization+compression / uncompressed byte
    commit_s: float                 # critical section per cluster (metadata+reserve)
    page_commit_s: float            # critical section per page (unbuffered)
    compression_ratio: float        # compressed/uncompressed
    cluster_bytes: int              # uncompressed bytes per cluster
    pages_per_cluster: int
    # futex wake + context switch per CONTENDED acquisition: this is the
    # mechanism behind the paper's 27,000-futex unbuffered collapse (§6.1)
    handoff_s: float = 10e-6


@dataclass(frozen=True)
class Device:
    bw: Optional[float] = None      # bytes/s, None = infinite (/dev/null)
    bw_prealloc: Optional[float] = None


@dataclass
class SimResult:
    wall_s: float
    uncompressed_bytes: int
    compressed_bytes: int
    lock_acquisitions: int
    lock_wait_s: float
    lock_held_s: float
    device_busy_s: float

    @property
    def bandwidth_compressed(self) -> float:
        return self.compressed_bytes / self.wall_s

    @property
    def bandwidth_uncompressed(self) -> float:
        return self.uncompressed_bytes / self.wall_s


def simulate(
    n_threads: int,
    clusters_per_thread: int,
    costs: Costs,
    device: Device = Device(),
    n_cores: int = 64,
    buffered: bool = True,
    fallocate: bool = False,
    write_outside_lock: bool = False,
    independent_writers: bool = False,
) -> SimResult:
    """Event-driven replay of the writer protocol."""
    slow = max(1.0, n_threads / n_cores)   # core oversubscription
    seal_s = costs.seal_s_per_byte * costs.cluster_bytes * slow
    comp_bytes = int(costs.cluster_bytes * costs.compression_ratio)
    bw = (device.bw_prealloc if (fallocate and device.bw_prealloc)
          else device.bw)

    # lock + device as busy-until resources
    lock_free_at = [0.0] * (n_threads if independent_writers else 1)
    dev_free_at = 0.0
    lock_acq = 0
    lock_wait = 0.0
    lock_held = 0.0
    dev_busy = 0.0
    done_at = 0.0

    units_per_cluster = 1 if buffered else costs.pages_per_cluster
    unit_commit_s = costs.commit_s if buffered else costs.page_commit_s
    unit_bytes = comp_bytes // units_per_cluster

    # per-thread timeline; process threads round-robin by next event time
    pq = [(0.0, t, 0, 0) for t in range(n_threads)]  # (time, thread, cluster, unit)
    heapq.heapify(pq)
    sealed_at: Dict[int, float] = {}

    while pq:
        t_now, th, cl, unit = heapq.heappop(pq)
        if cl >= clusters_per_thread:
            done_at = max(done_at, t_now)
            continue
        if unit == 0:
            # seal the cluster (no lock) then start committing units
            t_sealed = t_now + seal_s
            heapq.heappush(pq, (t_sealed, th, cl, 1))
            continue
        # commit one unit: acquire lock -> reserve+meta (+ write inside)
        li = th if independent_writers else 0
        contended = lock_free_at[li] > t_now
        start = max(t_now, lock_free_at[li])
        lock_wait += start - t_now
        lock_acq += 1
        held = unit_commit_s + (costs.handoff_s if contended else 0.0)
        write_s = 0.0
        if bw is not None:
            write_s = unit_bytes / bw
        if write_outside_lock or bw is None:
            # /dev/null write cost is ~0; opt-2 moves write out of the lock
            lock_free_at[li] = start + held
            lock_held += held
            end = start + held
            if bw is not None:
                dstart = max(end, dev_free_at)
                dev_free_at = dstart + write_s
                dev_busy += write_s
                end = dstart + write_s
        else:
            dstart = max(start + held, dev_free_at)
            dev_free_at = dstart + write_s
            dev_busy += write_s
            end = dstart + write_s
            lock_free_at[li] = end
            lock_held += end - start
        if unit < units_per_cluster:
            heapq.heappush(pq, (end, th, cl, unit + 1))
        else:
            heapq.heappush(pq, (end, th, cl + 1, 0))

    total_unc = n_threads * clusters_per_thread * costs.cluster_bytes
    total_comp = n_threads * clusters_per_thread * comp_bytes
    return SimResult(
        wall_s=done_at,
        uncompressed_bytes=total_unc,
        compressed_bytes=total_comp,
        lock_acquisitions=lock_acq,
        lock_wait_s=lock_wait,
        lock_held_s=lock_held,
        device_busy_s=dev_busy,
    )
