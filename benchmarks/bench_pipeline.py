"""Training-data pipeline benchmark: host vs fused device decode (§9).

Measures tokens/second delivered into a dummy jitted train step by
:class:`repro.pipeline.PackedLoader` on the synthetic tokenized corpus
(``pipeline.ingest.synth_corpus``), across three cells:

 1. **host** — the numpy engine: ``read_cluster`` + per-document Python
    packing, ``jnp.asarray`` copy into the step.
 2. **device** — the fused device decode chain with the overlap pipeline
    disabled (``prefetch_clusters=0``): stored page bytes upload once,
    decode + packing run as jitted device ops, but cluster *N+1* waits
    for cluster *N*.
 3. **device+overlap** — the full §9 path: the prefetch pool runs
    cluster *N+1*'s pread + entropy decode + H2D upload while cluster
    *N* decodes and packs on device.

Run at codec ``none`` (the decode-bound configuration the tokens/s win
is measured on) and ``zlib`` (decompression-bound; the overlap hides it
behind the device half).  Every cell's batches are asserted
BIT-IDENTICAL to the host engine's before timing — the speed cells never
run unverified code paths.  ``device_decode="auto"`` on this CPU
container compiles the jnp oracle ops through XLA (the Pallas kernels
engage on TPU; interpret-mode identity is covered by
``tests/test_device_decode.py`` and the ``pallas-interpret`` CI job).

Emits ``BENCH_pipeline.json`` (repo root by default).  Scratch files
live in ``benchmarks/_scratch_pipeline/`` (gitignored).

Run:  PYTHONPATH=src python benchmarks/bench_pipeline.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import time
from pathlib import Path

import numpy as np

from _harness import REPO_ROOT  # noqa: F401

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.reader import ReadOptions  # noqa: E402
from repro.core.writer import WriteOptions  # noqa: E402
from repro.pipeline import PackedLoader, ingest_corpus, synth_corpus  # noqa: E402

SCRATCH = REPO_ROOT / "benchmarks" / "_scratch_pipeline"

BATCH, SEQ = 8, 512
# Nested-data workload: many short collections per entry (mean 48
# elements), the regime the paper's formats target — the host engine
# pays its per-document Python loop on every entry, the device engine
# packs the whole cluster in one jitted call regardless of entry count.
MEAN_LEN = 48


@jax.jit
def _dummy_step(tokens, labels):
    """Stands in for a train step: consumes the batch on device."""
    return jnp.sum(tokens.astype(jnp.float32)) + jnp.sum(labels == 0)


def _loader(path: str, cell: str) -> PackedLoader:
    if cell == "host":
        return PackedLoader(path, BATCH, SEQ, device="host")
    prefetch = 0 if cell == "device" else 1
    return PackedLoader(
        path, BATCH, SEQ, device="device",
        read_options=ReadOptions(device_decode="auto",
                                 prefetch_clusters=prefetch,
                                 decode_workers=2 if prefetch else 0),
    )


def assert_identity(path: str, n_batches: int) -> None:
    """Every cell emits the host engine's exact batches, from a fresh
    cursor and from a mid-stream state() resume."""
    loaders = {cell: _loader(path, cell) for cell in
               ("host", "device", "device_overlap")}
    its = {c: ld.batches() for c, ld in loaders.items()}
    for k in range(n_batches):
        want = {kk: np.asarray(v) for kk, v in next(its["host"]).items()}
        for cell in ("device", "device_overlap"):
            got = next(its[cell])
            for kk in ("tokens", "labels"):
                np.testing.assert_array_equal(
                    np.asarray(got[kk]), want[kk],
                    err_msg=f"{cell} batch {k} {kk}")
    # mid-stream resume equivalence across engines
    state = loaders["device"].state()
    h2 = PackedLoader(path, BATCH, SEQ, state=state, device="host")
    d2 = _loader(path, "device_overlap")
    d2.load_state(state)
    gh, gd = h2.batches(), d2.batches()
    for k in range(4):
        want, got = next(gh), next(gd)
        for kk in ("tokens", "labels"):
            np.testing.assert_array_equal(
                np.asarray(got[kk]), np.asarray(want[kk]),
                err_msg=f"resume batch {k} {kk}")
    for ld in loaders.values():
        ld.close()
    h2.close(), d2.close()


def _epoch_batches(path: str) -> int:
    """Batches per epoch of the packed stream (docs + EOS separators)."""
    ld = _loader(path, "host")
    col_val = ld.reader.schema.column_of_path["tokens._0"]
    stream = int(ld.reader.total_elements[col_val]) + ld.reader.n_entries
    ld.close()
    return max(1, stream // (BATCH * (SEQ + 1)))


def bench_cell(path: str, cell: str, n_batches: int, repeats: int) -> dict:
    best = float("inf")
    stats = None
    for _ in range(repeats):
        ld = _loader(path, cell)
        it = ld.batches()
        # warm one full epoch: compiles the step and every per-cluster
        # jitted pack/slice shape, and faults the file into page cache —
        # the timed window then measures steady-state decode + packing
        warm = max(1, n_batches // 2)
        for _k in range(warm):
            b = next(it)
            _dummy_step(b["tokens"], b["labels"]).block_until_ready()
        t0 = time.perf_counter()
        for _k in range(n_batches):
            b = next(it)
            _dummy_step(b["tokens"], b["labels"]).block_until_ready()
        wall = time.perf_counter() - t0
        if wall < best:
            best = wall
            r = ld.reader.stats
            stats = {"device_clusters": r.device_clusters,
                     "h2d_ms": round(r.h2d_ns / 1e6, 2),
                     "wait_ms": round(r.wait_ns / 1e6, 2)}
        ld.close()
    toks = n_batches * BATCH * SEQ
    return {"wall_s": round(best, 4),
            "tokens_per_s": round(toks / best),
            **(stats or {})}


def run(n_docs: int, epochs: int, repeats: int, out_path: Path) -> dict:
    SCRATCH.mkdir(parents=True, exist_ok=True)
    out: dict = {
        "benchmark": "bench_pipeline",
        "batch": BATCH, "seq_len": SEQ,
        "n_docs": n_docs, "mean_len": MEAN_LEN, "epochs_timed": epochs,
        "cpu_count": os.cpu_count(),
        "jax_backend": jax.default_backend(),
        "identity": "asserted bit-identical (host vs device engines)",
        "codecs": {},
    }
    try:
        for codec in ("none", "zlib"):
            path = str(SCRATCH / f"corpus_{codec}.rntj")
            ingest_corpus(
                synth_corpus(n_docs, seed=7, mean_len=MEAN_LEN), path,
                n_workers=4,
                options=WriteOptions(codec=codec, level=1,
                                     cluster_bytes=2 * 1024 * 1024),
            )
            assert_identity(path, n_batches=6)
            # time whole epochs: every cell decodes every cluster the
            # same number of times (no amortization mismatch between
            # the per-doc host pull and the per-cluster device pull)
            n_batches = _epoch_batches(path) * epochs
            out["codecs_n_batches_%s" % codec] = n_batches
            cells = {}
            for cell in ("host", "device", "device_overlap"):
                cells[cell] = bench_cell(path, cell, n_batches, repeats)
                print(f"{codec:5s} {cell:15s} "
                      f"{cells[cell]['tokens_per_s']:>12,} tokens/s")
            cells["speedup_device_overlap_vs_host"] = round(
                cells["device_overlap"]["tokens_per_s"]
                / cells["host"]["tokens_per_s"], 2)
            out["codecs"][codec] = cells
    finally:
        shutil.rmtree(SCRATCH, ignore_errors=True)
    out_path.write_text(json.dumps(out, indent=1))
    print(f"wrote {out_path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload for CI smoke runs")
    ap.add_argument("--out", type=str,
                    default=str(REPO_ROOT / "BENCH_pipeline.json"))
    args = ap.parse_args()
    n_docs = 16_000 if args.quick else 60_000
    epochs = 1 if args.quick else 2
    repeats = 2 if args.quick else 3
    run(n_docs, epochs, repeats, Path(args.out))


if __name__ == "__main__":
    main()
